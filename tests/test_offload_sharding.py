"""Sharded multi-device offload plane: bit-exactness vs. the single-device
executor, shard-local Freivalds detection + single-shard recovery,
per-device quarantine/probation, straggler hedging, per-step ShardPolicy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import plan as PL
from repro.core.origami import OrigamiExecutor
from repro.kernels.limb_matmul.ops import field_matmul
from repro.models import model as M
from repro.parallel.offload_sharding import OffloadPlane
from repro.privacy.data import make_batch
from repro.runtime.devices import DeviceHealthConfig, DevicePool
from repro.runtime.faults import DishonestDevice, FaultSpec

KEY = jax.random.PRNGKey(7)


@pytest.fixture(scope="module")
def vgg():
    cfg = get_smoke("vgg16")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"images": jnp.asarray(make_batch(0, 2, cfg.image_size))}
    return cfg, params, batch


@pytest.fixture(scope="module")
def ref_logits(vgg):
    cfg, params, batch = vgg
    ex = OrigamiExecutor(cfg, params, mode="origami", precompute=True)
    return np.asarray(ex.infer(batch, session_key=KEY).logits)


def _pooled(vgg, pool, **kw):
    cfg, params, batch = vgg
    kw.setdefault("mode", "origami")
    kw.setdefault("precompute", True)
    return OrigamiExecutor(cfg, params, devices=pool, **kw)


# ---------------------------------------------------------------------------
# bit-exactness vs the single-device executor (same session keys)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shard", ["rows", "shares"])
def test_two_device_bit_exact(vgg, ref_logits, shard):
    cfg, params, batch = vgg
    pool = DevicePool(2)
    ex = _pooled(vgg, pool, shard=shard)
    r = ex.infer(batch, session_key=KEY)
    np.testing.assert_array_equal(np.asarray(r.logits), ref_logits)
    n_ops = r.sharding.ops
    assert n_ops > 0
    # every shard of every op dispatched AND checked (shard-local
    # verification is structural to the plane)
    assert r.sharding.dispatches == 2 * n_ops
    assert r.sharding.checks == 2 * n_ops
    assert r.sharding.failures == 0
    # the precompute ring carried the per-shard fold vectors
    assert ex.cache is not None and ex.cache.shards == 2
    pool.close()


def test_live_factor_path_bit_exact(vgg, ref_logits):
    """No precompute cache: shard folds derive live, result unchanged."""
    pool = DevicePool(2)
    ex = _pooled(vgg, pool, precompute=False)
    r = ex.infer(batch=vgg[2], session_key=KEY)
    np.testing.assert_array_equal(np.asarray(r.logits), ref_logits)
    pool.close()


def test_unfused_impl_bit_exact(vgg):
    cfg, params, batch = vgg
    single = OrigamiExecutor(cfg, params, mode="origami", precompute=True,
                             impl="unfused")
    want = np.asarray(single.infer(batch, session_key=KEY).logits)
    pool = DevicePool(2)
    ex = _pooled(vgg, pool, impl="unfused")
    got = np.asarray(ex.infer(batch, session_key=KEY).logits)
    np.testing.assert_array_equal(got, want)
    pool.close()


def test_more_devices_than_rows_bit_exact(vgg, ref_logits):
    """fc ops have t = batch (2) < 4 shards: empty shards are skipped,
    result still bit-exact."""
    pool = DevicePool(4)
    ex = _pooled(vgg, pool, mode="slalom")      # includes the fc/logits ops
    single = OrigamiExecutor(vgg[0], vgg[1], mode="slalom", precompute=True)
    want = np.asarray(single.infer(vgg[2], session_key=KEY).logits)
    got = np.asarray(ex.infer(vgg[2], session_key=KEY).logits)
    np.testing.assert_array_equal(got, want)
    pool.close()


# ---------------------------------------------------------------------------
# shard-local detection, single-shard retry, per-device quarantine
# ---------------------------------------------------------------------------

def test_dishonest_device_shard_local_recovery(vgg, ref_logits):
    pool = DevicePool(2, faults={1: DishonestDevice(FaultSpec("bit_flip"))},
                      health=DeviceHealthConfig(quarantine_after=100))
    ex = _pooled(vgg, pool)
    r = ex.infer(vgg[2], session_key=KEY)
    # recovered bit-exactly, and every corruption was caught SHARD-locally
    np.testing.assert_array_equal(np.asarray(r.logits), ref_logits)
    sh = r.sharding
    assert sh.failures == sh.ops        # device 1 corrupted its shard of
    assert sh.retries == sh.failures    # every op; ONLY those shards were
    assert sh.enclave_shards == 0       # re-dispatched — nothing recomputed
    assert sh.dispatches == 2 * sh.ops + sh.retries
    # blame lands on the device, not the op: the op-level report is clean
    # (no batch-level retry/recompute needed) but the response is flagged
    assert r.integrity.ok
    assert sh.flagged
    assert pool.slots[1].verify_failures == sh.failures
    assert pool.slots[0].verify_failures == 0
    pool.close()


def test_shares_mode_never_moves_a_share_between_devices(vgg, ref_logits):
    """A failed share is recomputed by the ENCLAVE, never re-dispatched —
    a device holding two shares of one op could sum them into the full
    blinded tensor, the exact reconstruction shares mode exists to
    prevent."""
    pool = DevicePool(2, faults={1: DishonestDevice(FaultSpec("bit_flip"))},
                      health=DeviceHealthConfig(quarantine_after=100))
    ex = _pooled(vgg, pool, shard="shares")
    r = ex.infer(vgg[2], session_key=KEY)
    np.testing.assert_array_equal(np.asarray(r.logits), ref_logits)
    sh = r.sharding
    assert sh.failures == sh.ops
    assert sh.retries == 0                    # confinement: no re-dispatch
    assert sh.enclave_shards == sh.failures   # enclave recomputed them
    # the honest device received exactly one share per op
    assert pool.slots[0].dispatches == sh.ops
    pool.close()


def test_per_device_quarantine_keeps_healthy_serving(vgg, ref_logits):
    pool = DevicePool(2, faults={1: DishonestDevice(FaultSpec("bit_flip"))},
                      health=DeviceHealthConfig(quarantine_after=2,
                                                probation_after=10 ** 6))
    ex = _pooled(vgg, pool)
    ex.infer(vgg[2], session_key=KEY)
    assert pool.slots[1].quarantined
    assert not pool.slots[0].quarantined
    # the healthy device alone keeps serving blinded offload, bit-exact,
    # with no further failures and no enclave fallback
    before = pool.slots[1].dispatches
    r = ex.infer(vgg[2], session_key=jax.random.fold_in(KEY, 1))
    single = OrigamiExecutor(vgg[0], vgg[1], mode="origami", precompute=True)
    want = np.asarray(single.infer(
        vgg[2], session_key=jax.random.fold_in(KEY, 1)).logits)
    np.testing.assert_array_equal(np.asarray(r.logits), want)
    assert r.sharding.failures == 0 and r.sharding.enclave_shards == 0
    assert pool.slots[1].dispatches == before     # benched: no traffic
    pool.close()


def test_probation_restores_healed_device(vgg, ref_logits):
    pool = DevicePool(2, faults={1: DishonestDevice(FaultSpec("bit_flip"))},
                      health=DeviceHealthConfig(quarantine_after=1,
                                                probation_after=1))
    ex = _pooled(vgg, pool)
    ex.infer(vgg[2], session_key=KEY)
    assert pool.slots[1].quarantined
    pool.slots[1].fault = None                    # transient fault heals
    r = ex.infer(vgg[2], session_key=jax.random.fold_in(KEY, 2))
    assert r.sharding.probes >= 1
    assert pool.slots[1].restores == 1
    assert not pool.slots[1].quarantined          # back in the pool
    assert pool.n_healthy() == 2
    pool.close()


def test_all_devices_quarantined_enclave_fallback(vgg, ref_logits):
    pool = DevicePool(1, faults={0: DishonestDevice(FaultSpec("bit_flip"))},
                      health=DeviceHealthConfig(quarantine_after=1,
                                                probation_after=10 ** 6))
    ex = _pooled(vgg, pool)
    r = ex.infer(vgg[2], session_key=KEY)
    # no healthy device and no spare to retry on: the enclave computes the
    # failed shards itself — still bit-exact
    np.testing.assert_array_equal(np.asarray(r.logits), ref_logits)
    assert r.sharding.enclave_shards >= 1
    r2 = ex.infer(vgg[2], session_key=KEY)
    np.testing.assert_array_equal(np.asarray(r2.logits), ref_logits)
    assert r2.sharding.dispatches == 0            # fully enclave-resident
    pool.close()


# ---------------------------------------------------------------------------
# straggler hedging (plane-level: no executor, tiny shapes)
# ---------------------------------------------------------------------------

def test_straggler_hedging_duplicates_and_wins():
    # 4 devices, 1 chronic straggler: the honest majority keeps the
    # watchdog P50 (and so the hedge deadline) at the fast-device level,
    # so the straggler's shard gets duplicated and the spare's verified
    # result wins
    from repro.core.blinding import blinding_stream
    x = blinding_stream(jax.random.fold_in(KEY, 1), (32, 16))
    w = blinding_stream(jax.random.fold_in(KEY, 2), (16, 16))
    want = np.asarray(field_matmul(x, w))
    pool = DevicePool(4, sim_delay_s={3: 0.30})
    plane = OffloadPlane(pool, mode="rows", hedging=True, matmul_impl="ref")
    for i in range(3):                            # warm the watchdog window
        jax.block_until_ready(plane.matmul(
            x, w, session_key=jax.random.fold_in(KEY, 10 + i), op_index=0))
    got = plane.matmul(x, w, session_key=jax.random.fold_in(KEY, 99),
                       op_index=0)
    np.testing.assert_array_equal(np.asarray(got), want)
    assert plane.totals.hedges >= 1
    assert plane.totals.failures == 0
    pool.close()


def test_hedging_off_never_duplicates():
    from repro.core.blinding import blinding_stream
    x = blinding_stream(jax.random.fold_in(KEY, 1), (32, 16))
    w = blinding_stream(jax.random.fold_in(KEY, 2), (16, 16))
    pool = DevicePool(2, sim_delay_s={1: 0.15})
    plane = OffloadPlane(pool, mode="rows", hedging=False, matmul_impl="ref")
    for i in range(4):
        plane.matmul(x, w, session_key=jax.random.fold_in(KEY, 20 + i),
                     op_index=0)
    assert plane.totals.hedges == 0
    assert plane.totals.dispatches == plane.totals.checks
    pool.close()


# ---------------------------------------------------------------------------
# per-step ShardPolicy (plan IR)
# ---------------------------------------------------------------------------

def test_shard_policy_device_group_restriction(vgg, ref_logits):
    cfg, params, batch = vgg
    p = cfg.origami.tier1_layers
    n = PL.num_blocks(cfg)
    plan = PL.make_plan(
        cfg, ["blinded"] * p + ["open"] * (n - p), boundary=p,
        shard={i: PL.ShardPolicy("rows", devices=(0,)) for i in range(p)})
    pool = DevicePool(2)
    ex = OrigamiExecutor(cfg, params, plan=plan, precompute=True,
                         devices=pool)
    r = ex.infer(batch, session_key=KEY)
    np.testing.assert_array_equal(np.asarray(r.logits), ref_logits)
    assert pool.slots[0].dispatches > 0
    assert pool.slots[1].dispatches == 0          # excluded by the group
    pool.close()


def test_inert_pool_keeps_jit(vgg):
    """A pool on an executor whose plan can never shard (scanned family,
    or no offloaded step) stays inert: the jitted trace is kept and no
    shard report is produced."""
    cfg, params, batch = vgg
    pool = DevicePool(2)
    ex = OrigamiExecutor(cfg, params, mode="enclave", devices=pool)
    assert not ex._plane_live                  # no offloaded steps
    r = ex.infer(batch, session_key=KEY)
    assert r.sharding is None
    assert pool.dispatches == 0
    pool.close()
    lm = get_smoke("smollm_135m")
    lm_params = M.init_params(lm, jax.random.PRNGKey(2))
    pool2 = DevicePool(2)
    ex2 = OrigamiExecutor(lm, lm_params, mode="origami", devices=pool2)
    assert not ex2._plane_live                 # scanned family
    pool2.close()


def test_shard_policy_in_digest_and_segments(vgg):
    cfg = vgg[0]
    base = PL.compile_mode(cfg, "origami")
    p = cfg.origami.tier1_layers
    n = PL.num_blocks(cfg)
    sharded = PL.make_plan(
        cfg, ["blinded"] * p + ["open"] * (n - p), boundary=p,
        shard={0: PL.ShardPolicy("shares")})
    assert sharded.digest != base.digest
    # shard-free plans keep their pre-sharding digests (cache keys,
    # attested measurements)
    plain = PL.make_plan(cfg, ["blinded"] * p + ["open"] * (n - p),
                         boundary=p)
    assert plain.digest == base.digest
    # a mid-run policy switch splits the blinded segment
    segs = [s for s in sharded.segments if s.regime == "blinded"]
    assert len(segs) == 2
    assert segs[0].shard == PL.ShardPolicy("shares")
    assert segs[1].shard is None
