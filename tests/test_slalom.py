"""Slalom protocol invariants: exactness of blinding, error bounds, telemetry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import blinding as B
from repro.core import slalom as SL
from repro.core.blinding import BlindingSpec
from repro.kernels.limb_matmul.ops import field_matmul
from repro.kernels.limb_matmul.ref import P, to_signed


def _ctx(seed=0):
    return SL.SlalomContext(jax.random.PRNGKey(seed), BlindingSpec())


def test_blinding_is_exact(rng):
    """Protocol invariant: blinded-offload result equals the *unblinded*
    quantized matmul bit-for-bit (the pad cancels exactly in Z_p)."""
    spec = BlindingSpec()
    x = rng.normal(size=(8, 64)).astype(np.float32)
    w = rng.normal(size=(64, 32)).astype(np.float32) / 8
    w_q, w_scale = B.quantize_weight(jnp.asarray(w), spec)
    x_scale = np.abs(x).max()
    from repro.kernels.blind.ref import quantize
    from repro.kernels.limb_matmul.ref import from_signed
    x_q = from_signed(quantize(jnp.asarray(x / x_scale), spec.k_act))
    plain = field_matmul(x_q, w_q)                          # no blinding
    key = jax.random.PRNGKey(42)
    r = B.blinding_stream(key, x.shape)
    u = B.unblinding_factor(r, w_q)
    x_b = B.blind_activations(jnp.asarray(x / x_scale), r, spec)
    y_b = field_matmul(x_b, w_q)
    unblinded = jnp.mod(y_b - u + P, P)
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(unblinded))


@pytest.mark.parametrize("t,din,dout", [(16, 64, 32), (32, 128, 96)])
def test_blinded_dense_error_bound(t, din, dout, rng):
    x = rng.normal(size=(t, din)).astype(np.float32)
    w = (rng.normal(size=(din, dout)) / np.sqrt(din)).astype(np.float32)
    got = np.asarray(SL.blinded_dense(_ctx(), {"w": jnp.asarray(w)},
                                      jnp.asarray(x)), np.float32)
    want = x @ w
    # absmax quantization: per-output error ~ sqrt(K) * step * scales
    spec = BlindingSpec()
    bound = (np.sqrt(din) * (np.abs(x).max() * np.abs(w).max())
             * (2.0 ** -spec.k_act + 2.0 ** -spec.k_w))
    assert np.abs(got - want).max() < bound, (np.abs(got - want).max(),
                                              bound)


def test_blinded_dense_with_bias(rng):
    x = rng.normal(size=(4, 32)).astype(np.float32)
    w = (rng.normal(size=(32, 8)) / 6).astype(np.float32)
    b = rng.normal(size=(8,)).astype(np.float32)
    got = np.asarray(SL.blinded_dense(
        _ctx(), {"w": jnp.asarray(w), "b": jnp.asarray(b)}, jnp.asarray(x)))
    want = x @ w + b
    assert np.abs(got - want).max() < 0.05


def test_blinded_conv_matches_conv(rng):
    from repro.models import layers as L
    x = rng.normal(size=(2, 8, 8, 3)).astype(np.float32)
    p = {"w": jnp.asarray(rng.normal(size=(3, 3, 3, 8)) / 5,
                          jnp.float32),
         "b": jnp.zeros((8,), jnp.float32)}
    got = np.asarray(SL.blinded_conv2d(_ctx(), p, jnp.asarray(x)))
    want = np.asarray(L.conv2d(p, jnp.asarray(x)))
    assert np.abs(got - want).max() < 0.05 * max(1.0, np.abs(want).max())


def test_stream_determinism_and_layer_separation():
    ctx1, ctx2 = _ctx(7), _ctx(7)
    k1a, k1b = ctx1.next_layer_key(), ctx1.next_layer_key()
    k2a = ctx2.next_layer_key()
    r1a = B.blinding_stream(k1a, (64,))
    r1b = B.blinding_stream(k1b, (64,))
    r2a = B.blinding_stream(k2a, (64,))
    np.testing.assert_array_equal(np.asarray(r1a), np.asarray(r2a))
    assert not np.array_equal(np.asarray(r1a), np.asarray(r1b))


def test_telemetry_accounting(rng):
    ctx = _ctx()
    x = jnp.asarray(rng.normal(size=(4, 8, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 24)) / 4, jnp.float32)
    SL.blinded_dense(ctx, {"w": w}, x)
    t = ctx.telemetry
    assert t.calls == 1
    assert t.blinded_bytes == 4 * 8 * 16 * 4
    assert t.returned_bytes == 4 * 8 * 24 * 4
    assert t.offloaded_flops == 2 * 32 * 16 * 24
