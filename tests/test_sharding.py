"""Sharding plans: spec structure, conflict resolution, divisibility."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config
from repro.configs.base import MeshConfig
from repro.models import layers as L
from repro.models import model as M


def test_spec_dedup_first_wins():
    defs = {"w": L.ParamDef((8, 16, 32), "scaled",
                            ("experts", "embed", "ffn"))}
    rules = {"experts": "model", "embed": "data", "ffn": "model"}
    specs = L.param_specs(defs, rules)
    assert specs["w"] == P("model", "data", None)


def test_spec_divisibility_fallback():
    defs = {"w": L.ParamDef((6, 2728, 2048), "scaled",
                            ("layers", "ffn", "embed"))}
    rules = {"layers": None, "ffn": "model", "embed": "data"}
    specs = L.param_specs(defs, rules, {"model": 16, "data": 16})
    assert specs["w"] == P(None, None, "data")      # 2728 % 16 != 0


def test_spec_tuple_axes():
    defs = {"w": L.ParamDef((32, 64), "scaled", ("embed", "ffn"))}
    rules = {"embed": ("pod", "data"), "ffn": "model"}
    specs = L.param_specs(defs, rules, {"pod": 2, "data": 16, "model": 16})
    # 32 % (2*16) == 0 -> ("pod","data"); 64 % 16 == 0 -> "model"
    assert specs["w"] == P(("pod", "data"), "model")


@pytest.mark.parametrize("arch", ARCHS)
def test_spec_tree_structure_matches_params(arch):
    cfg = get_config(arch)
    defs = M.model_defs(cfg)
    rules = {"embed": "data", "ffn": "model", "heads_flat": "model",
             "kv_flat": "model", "vocab": "model", "experts": "model",
             "lora": "model", "layers": None}
    specs = L.param_specs(defs, rules, {"model": 16, "data": 16})
    abstract = M.abstract_params(cfg)
    assert jax.tree.structure(specs) == jax.tree.structure(abstract)
    # every spec's sharded dims divide the corresponding shape
    for s, a in zip(jax.tree.leaves(specs,
                                    is_leaf=lambda x: isinstance(x, P)),
                    jax.tree.leaves(abstract)):
        for dim, ax in zip(a.shape, tuple(s) + (None,) * 8):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for x in axes:
                n *= {"model": 16, "data": 16}[x]
            assert dim % n == 0, (arch, a.shape, s)


def test_make_plan_batch_axes():
    from repro.launch.mesh import make_host_mesh
    from repro.parallel.sharding import make_plan
    mesh = make_host_mesh(1, 1)
    for mp in (False, True):
        mcfg = MeshConfig(multi_pod=mp)
        for name, shape in SHAPES.items():
            plan = make_plan(get_config("yi_9b"), shape, mesh, mcfg,
                             "train" if shape.kind == "train" else "serve")
            if shape.global_batch == 1:
                assert plan.batch_axes == ()
                assert "model" in plan.seq_axes
            else:
                n = 32 if mp else 16
                assert shape.global_batch % n == 0 or plan.batch_axes == (
                    "data",)


def test_act_rules_constrain_noop_outside_context():
    from repro.parallel.act_sharding import constrain
    x = jnp.ones((4, 4))
    np.testing.assert_array_equal(np.asarray(constrain(x, "batch", "seq")),
                                  np.asarray(x))
