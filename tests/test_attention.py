"""Attention: flash vs naive (fwd+grad), decode vs forward, MLA absorption."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import attention as A
from repro.models import model as M


@pytest.mark.parametrize("S,H,KH,D", [(128, 4, 2, 16), (256, 9, 3, 8)])
def test_flash_matches_naive_forward(S, H, KH, D, rng):
    B = 2
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KH, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KH, D)), jnp.float32)
    flash = A.sdpa(q, k, v, causal=True, q_chunk=32, kv_chunk=64)
    naive = A.sdpa(q, k, v, causal=True, cost_mode=True)
    np.testing.assert_allclose(np.asarray(flash), np.asarray(naive),
                               rtol=2e-4, atol=2e-4)


def test_flash_grads_match_naive(rng):
    B, S, H, KH, D = 1, 128, 4, 2, 8
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KH, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KH, D)), jnp.float32)

    def loss(fn):
        return lambda *a: jnp.sum(jnp.sin(fn(*a)))

    g1 = jax.grad(loss(lambda q, k, v: A.sdpa(
        q, k, v, causal=True, q_chunk=32, kv_chunk=32)), (0, 1, 2))(q, k, v)
    g2 = jax.grad(loss(lambda q, k, v: A.sdpa(
        q, k, v, causal=True, cost_mode=True)), (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)


def test_windowed_attention_masks(rng):
    B, S, H, D = 1, 64, 2, 8
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    w16 = A.sdpa(q, k, v, causal=True, window=16, cost_mode=True)
    full = A.sdpa(q, k, v, causal=True, cost_mode=True)
    # early positions identical (window not binding), late differ
    np.testing.assert_allclose(np.asarray(w16[:, :16]),
                               np.asarray(full[:, :16]), rtol=1e-5,
                               atol=1e-5)
    assert not np.allclose(np.asarray(w16[:, -1]), np.asarray(full[:, -1]))


@pytest.mark.parametrize("arch", ["qwen2_5_14b", "smollm_135m"])
def test_gqa_decode_matches_forward(arch):
    cfg = get_smoke(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, T = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                                cfg.vocab_size)
    full = np.asarray(M.forward(params, {"tokens": tokens}, cfg).logits,
                      np.float32)
    caches = M.init_caches(cfg, B, T)
    for t in range(T):
        logits, caches = M.decode_step(params, tokens[:, t:t + 1], caches,
                                       jnp.int32(t), cfg)
        np.testing.assert_allclose(np.asarray(logits[:, 0], jnp.float32),
                                   full[:, t], rtol=0.05, atol=0.05)


def test_prefill_then_decode_continues_forward():
    cfg = get_smoke("yi_9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, T = 1, 12
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, T), 0,
                                cfg.vocab_size)
    full = np.asarray(
        M.forward(params, {"tokens": tokens}, cfg).logits, np.float32)
    logits_p, caches = M.prefill(params, {"tokens": tokens[:, :8]}, cfg,
                                 max_seq=T)
    np.testing.assert_allclose(np.asarray(logits_p[:, 0], jnp.float32),
                               full[:, 7], rtol=0.05, atol=0.05)
    for t in range(8, T):
        logits, caches = M.decode_step(params, tokens[:, t:t + 1], caches,
                                       jnp.int32(t), cfg)
        np.testing.assert_allclose(np.asarray(logits[:, 0], jnp.float32),
                                   full[:, t], rtol=0.05, atol=0.05)


def test_mla_absorbed_decode_matches_naive():
    cfg = get_smoke("minicpm3_4b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, T = 1, 6
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 1, cfg.d_model),
                          jnp.float32)
    blk = jax.tree.map(lambda t: t[0], params["blocks"])
    m = cfg.mla
    width = m.kv_lora_rank + m.qk_rope_head_dim
    cache = A.KVCache(k=jax.random.normal(jax.random.PRNGKey(2),
                                          (B, T, width)) * 0.1, v=None)
    y_abs, _ = A.mla_decode(blk["attn"], x, cache, jnp.int32(T - 1), cfg,
                            absorbed=True)
    y_nav, _ = A.mla_decode(blk["attn"], x, cache, jnp.int32(T - 1), cfg,
                            absorbed=False)
    np.testing.assert_allclose(np.asarray(y_abs, jnp.float32),
                               np.asarray(y_nav, jnp.float32),
                               rtol=5e-2, atol=5e-2)


def test_mla_forward_matches_prefill():
    cfg = get_smoke("minicpm3_4b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0,
                                cfg.vocab_size)
    full = M.forward(params, {"tokens": tokens}, cfg).logits
    last, _ = M.prefill(params, {"tokens": tokens}, cfg)
    np.testing.assert_allclose(np.asarray(last[:, 0], jnp.float32),
                               np.asarray(full[:, -1], jnp.float32),
                               rtol=0.05, atol=0.05)
