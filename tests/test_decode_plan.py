"""Decode-plan IR (core/plan.py §16): scan segments, digest coverage,
typed exclusions, and the config-zoo no-bit-rot sweep."""
import pytest

from repro.configs import ARCHS, PAPER_MODELS, get_smoke
from repro.core import integrity as IG
from repro.core import plan as PL


def _smoke(name):
    return get_smoke(name)


# ---------------------------------------------------------------------------
# ScanSegment / DecodePlan structure
# ---------------------------------------------------------------------------

def test_decode_plan_mirrors_base_segments():
    cfg = _smoke("smollm_135m")
    dplan = PL.make_decode_plan(cfg, max_steps=16)
    base = dplan.base
    assert len(dplan.scan) == len(base.segments)
    for seg, sseg in zip(base.segments, dplan.scan):
        assert (sseg.lo, sseg.hi, sseg.regime) == (seg.lo, seg.hi,
                                                   seg.regime)
        assert sseg.steps == (0, 16)
        # plain segments touch no factor material; offloaded ones bind
        # per-token slots from the ring
        expect = "none" if seg.regime == "plain" else "token"
        assert sseg.slot_binding == expect
    assert dplan.has_offload == base.has_offload


def test_decode_plan_attaches_per_step_integrity():
    cfg = _smoke("smollm_135m")
    pol = IG.IntegrityPolicy.sampled(0.5, k=3)
    dplan = PL.make_decode_plan(cfg, max_steps=8, integrity=pol)
    offloaded = [s for s in dplan.scan if s.regime != "plain"]
    assert offloaded and all(s.policy is pol for s in offloaded)
    assert all(s.policy is None for s in dplan.scan
               if s.regime == "plain")
    assert dplan.has_verification


def test_decode_digest_covers_scan_structure():
    """Attestation/AOT keys must distinguish decode plans from their base
    plan AND from each other (step range, policy, max_steps)."""
    cfg = _smoke("smollm_135m")
    d8 = PL.make_decode_plan(cfg, max_steps=8)
    d8b = PL.make_decode_plan(cfg, max_steps=8)
    d16 = PL.make_decode_plan(cfg, max_steps=16)
    dver = PL.make_decode_plan(cfg, max_steps=8,
                               integrity=IG.IntegrityPolicy.full(k=2))
    assert d8.digest == d8b.digest            # deterministic
    assert d8.digest != d8.base.digest        # distinct from forward plan
    assert len({d8.digest, d16.digest, dver.digest}) == 3
    assert d8.base.digest == d16.base.digest  # same base underneath


def test_scan_exclusion_is_typed_and_documented():
    """The former "scanned families fall back" branches are now a typed
    error naming the structural reason — and still a ValueError so legacy
    callers keep working."""
    assert issubclass(PL.ScanExclusion, ValueError)
    cfg = _smoke("qwen3_moe_235b")
    with pytest.raises(PL.ScanExclusion) as ei:
        PL.make_decode_plan(cfg, max_steps=4)
    msg = str(ei.value)
    assert "DESIGN.md" in msg and cfg.family in msg


def test_decode_families_gate():
    assert "dense" in PL.DECODE_FAMILIES
    for fam in ("moe", "hybrid", "ssm", "audio", "vlm", "cnn"):
        assert fam in PL._DECODE_EXCLUSIONS, fam


# ---------------------------------------------------------------------------
# config zoo: every dormant config must plan or raise the typed exclusion
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", list(ARCHS) + list(PAPER_MODELS))
def test_config_zoo_plans_or_excludes(name):
    """No silent bit-rot: every shipped config produces a valid layer
    program and compiles a forward plan; decode planning either succeeds
    (DECODE_FAMILIES) or raises the documented typed exclusion."""
    cfg = _smoke(name)
    prog = PL.program_for(cfg)
    assert prog.n_layers == PL.num_blocks(cfg) > 0
    plan = PL.compile_mode(cfg, "origami")
    assert plan.n_layers == prog.n_layers
    assert plan.digest
    if cfg.family in PL.DECODE_FAMILIES:
        dplan = PL.make_decode_plan(cfg, plan, max_steps=4)
        assert dplan.scan and dplan.digest != plan.digest
    else:
        with pytest.raises(PL.ScanExclusion, match="DESIGN.md"):
            PL.make_decode_plan(cfg, plan, max_steps=4)
