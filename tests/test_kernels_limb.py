"""Limb-matmul kernel: Pallas(interpret) vs pure-jnp oracle vs int64 truth."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.limb_matmul import ref
from repro.kernels.limb_matmul.ops import field_matmul


def _int64_oracle(x, w):
    return ((x.astype(np.int64) @ w.astype(np.int64)) % ref.P).astype(
        np.int32)


@settings(max_examples=30, deadline=None)
@given(st.integers(-ref.HALF, ref.HALF))
def test_limb_roundtrip_property(v):
    s = jnp.asarray([v], jnp.int32)
    back = ref.from_limbs(ref.to_limbs(s))
    assert int(back[0]) == v


def test_limb_roundtrip_bulk(rng):
    s = rng.integers(-ref.HALF, ref.HALF + 1, size=(200_000,),
                     dtype=np.int32)
    back = np.asarray(ref.from_limbs(ref.to_limbs(jnp.asarray(s))))
    np.testing.assert_array_equal(back, s)


def test_limb_digits_in_int8_range(rng):
    s = rng.integers(-ref.HALF, ref.HALF + 1, size=(100_000,),
                     dtype=np.int32)
    l = np.asarray(ref.to_limbs(jnp.asarray(s)))
    assert l.dtype == np.int8


def test_signed_canonical_roundtrip(rng):
    v = rng.integers(0, ref.P, size=(10_000,), dtype=np.int32)
    back = np.asarray(ref.from_signed(ref.to_signed(jnp.asarray(v))))
    np.testing.assert_array_equal(back, v)


def test_mod_mul_pow256():
    y = jnp.asarray([0, 1, ref.P - 1, 12345], jnp.int32)
    for k in range(5):
        got = np.asarray(ref.mod_mul_pow256(y, k))
        want = (np.asarray(y, np.int64) * (256 ** k)) % ref.P
        np.testing.assert_array_equal(got, want.astype(np.int32))


@pytest.mark.parametrize("M,K,N", [
    (300, 72, 8),      # Kp > K: exercises the interpret re-encode branch
    (256, 1152, 64),   # multi-k-step grid: scratch accumulator across steps
])
def test_fused_blinded_matmul_backends_bit_identical(M, K, N, rng):
    """The fused chain's pure-jnp fallback and the Pallas(interpret) kernels
    must agree bit-for-bit (docstring contract of fused_blinded_matmul)."""
    from repro.kernels.limb_matmul.ops import (encode_weight_planes,
                                               fused_blinded_matmul)
    x = jnp.asarray(rng.normal(size=(M, K)), np.float32)
    r = jnp.asarray(rng.integers(0, ref.P, (M, K)), jnp.int32)
    w_q = ref.from_signed(jnp.asarray(rng.integers(-128, 128, (K, N)),
                                      jnp.int32))
    w_limbs = encode_weight_planes(w_q)
    u = field_matmul(r, w_q, impl="ref")
    args = (x, r, w_limbs, u, jnp.float32(0.5), jnp.float32(1e-4))
    kw = dict(k_bits=8, k_out_bits=15)
    got_ref = np.asarray(fused_blinded_matmul(*args, impl="ref", **kw))
    got_int = np.asarray(fused_blinded_matmul(*args, impl="interpret", **kw))
    np.testing.assert_array_equal(got_ref, got_int)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 80), st.integers(1, 300), st.integers(1, 60),
       st.integers(0, 2 ** 31 - 1))
def test_ref_matmul_matches_int64(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, ref.P, size=(m, k), dtype=np.int32)
    w = rng.integers(0, ref.P, size=(k, n), dtype=np.int32)
    got = np.asarray(ref.field_matmul_ref(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_array_equal(got, _int64_oracle(x, w))


@pytest.mark.parametrize("shape", [
    (8, 16, 8),              # tiny (ref path)
    (128, 256, 128),         # single block
    (256, 1024, 256),        # exactly one kernel tile
    (300, 1500, 260),        # padding on all dims
    (512, 2048, 384),        # multi-tile grid
])
def test_pallas_interpret_matches_oracle(shape, rng):
    m, k, n = shape
    x = rng.integers(0, ref.P, size=(m, k), dtype=np.int32)
    w = rng.integers(0, ref.P, size=(k, n), dtype=np.int32)
    got = np.asarray(field_matmul(jnp.asarray(x), jnp.asarray(w),
                                  impl="interpret"))
    np.testing.assert_array_equal(got, _int64_oracle(x, w))


def test_pallas_block_shape_sweep(rng):
    m, k, n = 256, 2048, 256
    x = rng.integers(0, ref.P, size=(m, k), dtype=np.int32)
    w = rng.integers(0, ref.P, size=(k, n), dtype=np.int32)
    want = _int64_oracle(x, w)
    for bm, bn, bk in [(128, 128, 512), (256, 256, 1024), (128, 256, 2048)]:
        got = np.asarray(field_matmul(jnp.asarray(x), jnp.asarray(w),
                                      impl="interpret", bm=bm, bn=bn, bk=bk))
        np.testing.assert_array_equal(got, want)


def test_extreme_field_values():
    x = jnp.asarray([[0, 1, ref.P - 1, ref.HALF, ref.HALF + 1]], jnp.int32)
    w = jnp.asarray([[ref.P - 1], [1], [ref.P - 1], [ref.HALF], [2]],
                    jnp.int32)
    got = np.asarray(field_matmul(x, w, impl="ref"))
    want = _int64_oracle(np.asarray(x), np.asarray(w))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("shape", [
    (16, 8, 1),              # Freivalds fold, k=1 (tiny)
    (256, 1024, 2),          # one kernel tile, k=2
    (300, 1100, 1),          # padding on both dims
])
def test_fold_kernel_matches_oracle(shape, rng):
    """The Pallas fold kernel (y @ s) mod p — the integrity layer's check
    primitive — must bit-match the int64 oracle, including the zero-padded
    fold lanes being stripped."""
    from repro.kernels.limb_matmul.ops import field_fold
    m, k, nf = shape
    y = rng.integers(0, ref.P, size=(m, k), dtype=np.int32)
    s = rng.integers(0, ref.P, size=(k, nf), dtype=np.int32)
    want = _int64_oracle(y, s)
    for impl in ("ref", "interpret"):
        got = np.asarray(field_fold(jnp.asarray(y), jnp.asarray(s),
                                    impl=impl))
        np.testing.assert_array_equal(got, want, err_msg=impl)
