"""Precomputed blinding pipeline: bit-exactness vs on-the-fly, stream reuse
guard, and the one-device-matmul-per-call telemetry claim."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import slalom as SL
from repro.core.blinding import BlindingSpec
from repro.core.origami import OrigamiExecutor
from repro.core.precompute import BlindedLayerCache
from repro.models import model as M


def _dense_cache(w, t, spec):
    recs = [{"kind": "dense", "w": jnp.asarray(w), "t": t,
             "d_in": w.shape[0], "d_out": w.shape[1]}]
    return BlindedLayerCache.from_records(recs, spec)


@pytest.mark.parametrize("impl", ["fused", "unfused"])
def test_dense_cached_bit_exact_vs_on_the_fly(impl, rng):
    spec = BlindingSpec()
    t, d_in, d_out = 16, 64, 32
    x = jnp.asarray(rng.normal(size=(t, d_in)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(d_in, d_out)) / 8, jnp.float32)
    key = jax.random.PRNGKey(3)

    ctx_live = SL.SlalomContext(key, spec, impl=impl)
    y_live = np.asarray(SL.blinded_dense(ctx_live, {"w": w}, x))

    cache = _dense_cache(w, t, spec)
    ctx_pre = SL.SlalomContext(key, spec, impl=impl,
                               factors=cache.session_factors(key))
    y_pre = np.asarray(SL.blinded_dense(ctx_pre, {"w": w}, x))
    np.testing.assert_array_equal(y_live, y_pre)


def test_executor_precompute_bit_exact_cnn(rng):
    """Tier-1 conv layers: cached factors reproduce the on-the-fly trace
    bit-for-bit (same streams, same quantized weights, same field math)."""
    cfg = get_smoke("vgg16")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"images": jnp.asarray(
        rng.normal(size=(2, cfg.image_size, cfg.image_size, 3)) * 0.5,
        jnp.float32)}
    key = jax.random.PRNGKey(11)
    live = OrigamiExecutor(cfg, params, mode="origami").infer(
        batch, session_key=key)
    pre = OrigamiExecutor(cfg, params, mode="origami",
                          precompute=True).infer(batch, session_key=key)
    np.testing.assert_array_equal(np.asarray(live.logits),
                                  np.asarray(pre.logits))


def test_executor_precompute_falls_back_under_scan():
    """LM blocks run under lax.scan (weights are tracers per traced call) —
    precompute must degrade gracefully to on-the-fly factors, bit-exact."""
    cfg = get_smoke("smollm_135m")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2),
                                          (2, 16), 0, cfg.vocab_size)}
    key = jax.random.PRNGKey(11)
    live = OrigamiExecutor(cfg, params, mode="origami").infer(
        batch, session_key=key)
    pre_ex = OrigamiExecutor(cfg, params, mode="origami", precompute=True)
    pre = pre_ex.infer(batch, session_key=key)
    assert pre_ex.cache is None and pre_ex.precompute is False
    np.testing.assert_array_equal(np.asarray(live.logits),
                                  np.asarray(pre.logits))


def test_stream_reuse_guard(rng):
    """Distinct (session, layer, step) triples must never yield the same
    pad r — one-time-pad reuse would break the privacy argument."""
    spec = BlindingSpec()
    w = jnp.asarray(rng.normal(size=(32, 16)) / 6, jnp.float32)
    recs = [{"kind": "dense", "w": w, "t": 8, "d_in": 32, "d_out": 16}
            for _ in range(2)]
    cache = BlindedLayerCache.from_records(recs, spec)
    streams = {}
    for skey in (jax.random.PRNGKey(1), jax.random.PRNGKey(2)):
        for step in (0, 1):
            for i, f in enumerate(cache.session_factors(skey, step)):
                streams[(int(skey[1]), i, step)] = np.asarray(f["r"])
    keys = list(streams)
    for a in range(len(keys)):
        for b in range(a + 1, len(keys)):
            assert not np.array_equal(streams[keys[a]], streams[keys[b]]), \
                (keys[a], keys[b])


def test_precompute_removes_request_path_factor_matmul():
    """With the cache active the request trace performs exactly one device
    field-matmul per blinded call and zero enclave r@W_q matmuls."""
    cfg = get_smoke("vgg16")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"images": jnp.zeros((1, cfg.image_size, cfg.image_size, 3))}

    live = OrigamiExecutor(cfg, params, mode="origami")
    live.infer(batch)
    assert live.telemetry.calls > 0
    assert live.telemetry.device_matmuls == live.telemetry.calls
    assert live.telemetry.enclave_matmuls == live.telemetry.calls

    pre = OrigamiExecutor(cfg, params, mode="origami", precompute=True)
    pre.infer(batch)
    assert pre.telemetry.calls == live.telemetry.calls
    assert pre.telemetry.device_matmuls == pre.telemetry.calls
    assert pre.telemetry.enclave_matmuls == 0
    # the factor matmuls moved off-path into the cache, not vanished
    assert pre.cache.factor_matmuls == pre.cache.num_layers


def test_prefetch_take_semantics():
    cfg = get_smoke("vgg16")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    ex = OrigamiExecutor(cfg, params, mode="origami", precompute=True)
    batch = {"images": jnp.zeros((1, cfg.image_size, cfg.image_size, 3))}
    ex.build_cache(batch)
    key = jax.random.PRNGKey(9)
    ex.prepare_session(key)
    got = ex.cache.take(key)
    assert len(got) == ex.cache.num_layers
    # taking pops the buffer: next take recomputes (fresh list object)
    again = ex.cache.take(key)
    assert again is not got
    for a, b in zip(got, again):
        np.testing.assert_array_equal(np.asarray(a["r"]), np.asarray(b["r"]))


def test_cache_rebuilds_on_batch_shape_change():
    cfg = get_smoke("vgg16")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    ex = OrigamiExecutor(cfg, params, mode="origami", precompute=True)
    b1 = {"images": jnp.zeros((1, cfg.image_size, cfg.image_size, 3))}
    b2 = {"images": jnp.zeros((2, cfg.image_size, cfg.image_size, 3))}
    ex.infer(b1)
    c1 = ex.cache
    ex.infer(b2)
    assert ex.cache is not c1
    assert ex.cache.layers[0].t == 2 * c1.layers[0].t
    # recurring shape (padding bucket) reuses the earlier cache, no rebuild
    ex.infer(b1)
    assert ex.cache is c1
