"""Pallas flash-attention kernel vs oracle: shape/dtype/block sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import mha_ref


@pytest.mark.parametrize("B,S,H,KH,D", [
    (2, 256, 8, 2, 64),      # GQA 4:1
    (1, 512, 4, 4, 128),     # MHA, MXU-aligned D
    (2, 128, 6, 3, 32),      # odd head count
])
def test_flash_matches_ref(B, S, H, KH, D, rng):
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KH, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KH, D)), jnp.float32)
    got = np.asarray(flash_attention(q, k, v, causal=True, bq=64, bk=64,
                                     impl="interpret"))
    want = np.asarray(mha_ref(q, k, v, causal=True))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("bq,bk", [(64, 64), (128, 64), (64, 128),
                                   (256, 256)])
def test_flash_block_sweep(bq, bk, rng):
    B, S, H, KH, D = 1, 256, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KH, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KH, D)), jnp.float32)
    got = np.asarray(flash_attention(q, k, v, causal=True, bq=bq, bk=bk,
                                     impl="interpret"))
    want = np.asarray(mha_ref(q, k, v, causal=True))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_flash_dtypes(dtype, tol, rng):
    B, S, H, KH, D = 1, 128, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, KH, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, KH, D)), dtype)
    got = np.asarray(flash_attention(q, k, v, causal=True, bq=64, bk=64,
                                     impl="interpret"), np.float32)
    want = np.asarray(mha_ref(q, k, v, causal=True), np.float32)
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_flash_non_causal(rng):
    B, S, H, KH, D = 1, 128, 2, 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KH, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KH, D)), jnp.float32)
    got = np.asarray(flash_attention(q, k, v, causal=False, bq=64, bk=64,
                                     impl="interpret"))
    want = np.asarray(mha_ref(q, k, v, causal=False))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
