"""Liveness fault-tolerance plane (DESIGN.md §12): chaos schedule parsing,
deterministic liveness injectors, the plane's timeout/backoff/breaker
recovery ladder, engine degradation to enclave-only serving + automatic
recovery, scripted refill/sealing faults, and draining shutdown."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.blinding import blinding_stream
from repro.kernels.limb_matmul.ops import field_matmul
from repro.models import model as M
from repro.parallel.offload_sharding import LivenessConfig, OffloadPlane
from repro.privacy.data import make_batch
from repro.runtime.chaos import ChaosController, ChaosSchedule, RefillChaos
from repro.runtime.devices import (BREAKER_CLOSED, BREAKER_OPEN,
                                   DeviceHealthConfig, DevicePool)
from repro.runtime.engine import EngineConfig, ServingEngine
from repro.runtime.faults import (DeviceCrash, LivenessSpec,
                                  UnresponsiveDevice)
from repro.runtime.serving import PrivateInferenceServer, Request
from repro.runtime.sessions import SessionPool

DRILL = "dev0.crash@1-2,dev1.hang@1-2,refill@7-8,seal@10"


@pytest.fixture(scope="module")
def vgg():
    cfg = get_smoke("vgg16")
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


def _request(cfg, rid, rng):
    img = make_batch(rid, 1, cfg.image_size)[0]
    key = rng.integers(0, 2 ** 32 - 1, size=(2,), dtype=np.uint32)
    box = PrivateInferenceServer.client_seal(key, img, rid)
    return Request(rid=rid, box=box, shape=img.shape, session_key=key), key


def _operands(t=32, d_in=32, d_out=32):
    key = jax.random.PRNGKey(0)
    x = blinding_stream(jax.random.fold_in(key, 1), (t, d_in))
    w = blinding_stream(jax.random.fold_in(key, 2), (d_in, d_out))
    return x, w


# ---------------------------------------------------------------------------
# schedule mini-language
# ---------------------------------------------------------------------------

def test_schedule_parse_round_trip():
    sched = ChaosSchedule.parse(DRILL)
    assert str(sched) == DRILL
    assert len(sched.events) == 4
    assert sched.horizon == 11                  # last window ends at 10
    dev0 = sched.events[0]
    assert (dev0.layer, dev0.device, dev0.kind) == ("device", 0, "crash")
    assert dev0.active(1) and dev0.active(2)
    assert not dev0.active(0) and not dev0.active(3)
    seal = sched.events[3]
    assert seal.start == seal.stop == 10        # @a is the window [a, a]


def test_schedule_rejects_garbage():
    for bad in ("dev0.fliparoo@1", "crash@1", "dev0.crash", "refill@",
                "dev0.crash@2-", "", " , ", "devx.hang@1"):
        with pytest.raises(ValueError):
            ChaosSchedule.parse(bad)
    with pytest.raises(AssertionError):         # inverted window
        ChaosSchedule.parse("dev0.crash@5-2")


# ---------------------------------------------------------------------------
# liveness injectors: deterministic, per-class semantics
# ---------------------------------------------------------------------------

def _fired_pattern(seed, ops=8):
    inj = UnresponsiveDevice(LivenessSpec(kind="flaky", prob=0.6), seed=seed)
    pattern = []
    done = threading.Event()
    for op in range(ops):
        try:
            inj.perturb(op_index=op, cancel=done)
            pattern.append(False)
        except DeviceCrash:
            pattern.append(True)
    return pattern


def test_injector_replays_identically():
    a, b = _fired_pattern(seed=3), _fired_pattern(seed=3)
    assert a == b                               # same seed -> same run
    assert any(a) and not all(a)                # prob 0.6 actually gates


def test_flaky_decay_lets_retries_through():
    # prob 1.0, decay 0: attempt 0 on an op always crashes, attempt 1
    # never does — the minimal "transient" the backoff ladder must absorb
    inj = UnresponsiveDevice(LivenessSpec(kind="flaky", decay=0.0))
    done = threading.Event()
    with pytest.raises(DeviceCrash):
        inj.perturb(op_index=5, cancel=done)
    inj.perturb(op_index=5, cancel=done)        # retry passes
    assert inj.fired == 1


def test_hang_parks_on_cancel_event():
    inj = UnresponsiveDevice(LivenessSpec(kind="hang"))
    cancel = threading.Event()
    cancel.set()                                # abandoned before dispatch
    with pytest.raises(DeviceCrash):
        inj.perturb(op_index=0, cancel=cancel)


def test_brownout_delays_without_error():
    inj = UnresponsiveDevice(LivenessSpec(kind="brownout", delay_s=0.05))
    t0 = time.perf_counter()
    inj.perturb(op_index=0, cancel=threading.Event())
    assert time.perf_counter() - t0 >= 0.04
    assert inj.fired == 1


def test_injector_op_targeting():
    inj = UnresponsiveDevice(LivenessSpec(kind="crash", ops=(2,)))
    done = threading.Event()
    inj.perturb(op_index=0, cancel=done)        # untargeted: no-op
    with pytest.raises(DeviceCrash):
        inj.perturb(op_index=2, cancel=done)


# ---------------------------------------------------------------------------
# plane-level recovery ladder: containment -> retry -> breaker -> probe
# ---------------------------------------------------------------------------

def test_plane_contains_crashes_and_breaker_cycles():
    x, w = _operands()
    want = np.asarray(field_matmul(x, w))
    pool = DevicePool(2, health=DeviceHealthConfig(breaker_after=2,
                                                   breaker_cooldown=2))
    plane = OffloadPlane(pool, mode="rows", hedging=False,
                         liveness=LivenessConfig(timeout_floor_s=0.1,
                                                 cold_timeout_s=1.0))
    slot = pool.slots[0]
    slot.liveness = UnresponsiveDevice(LivenessSpec(kind="crash"))
    for op in range(4):                         # faulted window
        y = plane.matmul(x, w, session_key=jax.random.PRNGKey(op),
                         op_index=op)
        np.testing.assert_array_equal(np.asarray(y), want)
    assert plane.totals.crashes >= 2
    assert plane.totals.backoffs >= 1           # redispatch waited its turn
    assert slot.breaker == BREAKER_OPEN         # indicted after 2 consec
    assert not slot.available and pool.n_available() == 1
    assert slot.breaker_opens == 1

    slot.liveness = None                        # fault clears
    for op in range(4, 12):
        y = plane.matmul(x, w, session_key=jax.random.PRNGKey(op),
                         op_index=op)
        np.testing.assert_array_equal(np.asarray(y), want)
        if slot.breaker == BREAKER_CLOSED:
            break
    assert slot.breaker == BREAKER_CLOSED       # half-open probe verified
    assert slot.breaker_probes >= 1 and slot.breaker_closes == 1
    assert pool.n_available() == 2
    assert plane.totals.breaker_probes >= 1
    pool.close()


def test_plane_times_out_hung_device_and_abandons_queue():
    x, w = _operands()
    want = np.asarray(field_matmul(x, w))
    pool = DevicePool(2, health=DeviceHealthConfig(breaker_after=1,
                                                   breaker_cooldown=2))
    plane = OffloadPlane(pool, mode="rows", hedging=False,
                         liveness=LivenessConfig(timeout_floor_s=0.1,
                                                 cold_timeout_s=0.5))
    slot = pool.slots[1]
    slot.liveness = UnresponsiveDevice(LivenessSpec(kind="hang"))
    t0 = time.perf_counter()
    y = plane.matmul(x, w, session_key=jax.random.PRNGKey(0), op_index=0)
    np.testing.assert_array_equal(np.asarray(y), want)
    assert time.perf_counter() - t0 < 30        # hard timeout, not forever
    assert plane.totals.timeouts >= 1
    assert slot.abandons >= 1                   # wedged queue swapped out
    assert slot.breaker == BREAKER_OPEN
    slot.liveness = None
    pool.close()                                # parked worker released


def test_plane_single_device_falls_back_to_enclave():
    # no spare exists: after containment the shard recomputes in-enclave
    x, w = _operands()
    want = np.asarray(field_matmul(x, w))
    pool = DevicePool(1)
    plane = OffloadPlane(pool, mode="rows", hedging=False,
                         liveness=LivenessConfig(backoff_max_s=0.02))
    pool.slots[0].liveness = UnresponsiveDevice(LivenessSpec(kind="crash"))
    y = plane.matmul(x, w, session_key=jax.random.PRNGKey(0), op_index=0)
    np.testing.assert_array_equal(np.asarray(y), want)
    assert plane.totals.enclave_shards >= 1
    assert plane.totals.crashes >= 1
    pool.close()


def test_brownout_inflates_latency_without_indictment():
    x, w = _operands()
    pool = DevicePool(2)
    plane = OffloadPlane(pool, mode="rows", hedging=False,
                         liveness=LivenessConfig(timeout_floor_s=1.0))
    pool.slots[0].liveness = UnresponsiveDevice(
        LivenessSpec(kind="brownout", delay_s=0.05))
    for op in range(3):
        plane.matmul(x, w, session_key=jax.random.PRNGKey(op), op_index=op)
    assert plane.totals.crashes == 0 and plane.totals.timeouts == 0
    assert pool.slots[0].breaker == BREAKER_CLOSED
    assert pool.n_available() == 2
    pool.close()


# ---------------------------------------------------------------------------
# scripted refill faults (deterministic: synchronous prime)
# ---------------------------------------------------------------------------

def test_refill_chaos_contained_and_counted():
    pool = SessionPool(None, depth=2, background=False)
    chaos = ChaosController(ChaosSchedule.parse("refill@0-1"), sessions=pool)
    chaos.on_batch(0)                           # arm
    assert pool.refill_fault is not None
    pool.prime()                                # every prefetch raises
    assert pool.stats()["refill_errors"] == 2   # contained, counted
    assert chaos.refill_faults == 2
    chaos.on_batch(2)                           # disarm
    assert pool.refill_fault is None
    pool.acquire()                              # serving never stopped
    pool.prime()
    assert pool.stats()["refill_errors"] == 2   # no new failures
    pool.close()


def test_refill_fault_hook_raises_refill_chaos():
    pool = SessionPool(None, depth=1, background=False)
    chaos = ChaosController(ChaosSchedule.parse("refill@0"), sessions=pool)
    chaos.on_batch(0)
    with pytest.raises(RefillChaos):
        pool.refill_fault(0)
    pool.close()


# ---------------------------------------------------------------------------
# controller arming across layers
# ---------------------------------------------------------------------------

def test_controller_arms_and_disarms_device_injectors():
    pool = DevicePool(2)
    chaos = ChaosController(ChaosSchedule.parse("dev1.crash@2-3"), pool=pool)
    chaos.on_batch(0)
    assert pool.slots[1].liveness is None
    chaos.on_batch(2)
    inj = pool.slots[1].liveness
    assert inj is not None and inj.spec.kind == "crash"
    chaos.on_batch(3)
    assert pool.slots[1].liveness is inj        # window still open
    chaos.on_batch(4)
    assert pool.slots[1].liveness is None
    assert [(b, a) for b, _, a in chaos.log] == [(2, "arm"), (4, "disarm")]
    pool.close()


def test_controller_seal_window_flips_macs(vgg, rng):
    cfg, _ = vgg
    req, _key = _request(cfg, 0, rng)
    mac0 = np.uint32(req.box.mac)
    chaos = ChaosController(ChaosSchedule.parse("seal@1"))
    chaos.on_batch(0, requests=[req])
    assert np.uint32(req.box.mac) == mac0       # outside the window
    chaos.on_batch(1, requests=[req])
    assert np.uint32(req.box.mac) == mac0 ^ np.uint32(1)
    assert chaos.seal_corruptions == 1
    chaos.quiesce()
    assert not chaos.snapshot()["armed"]


# ---------------------------------------------------------------------------
# engine: degrade to enclave-only, recover, seal isolation — bit-exact
# ---------------------------------------------------------------------------

def test_engine_degrades_recovers_and_stays_bit_exact(vgg, rng):
    cfg, params = vgg
    per = 2            # eager (plane) and jitted logits only agree for t>=2
    schedule = ChaosSchedule.parse("dev0.crash@1,dev1.hang@1,seal@3")
    n_batches = schedule.horizon + 5
    reqs, keys = zip(*[_request(cfg, i, rng)
                       for i in range(per * n_batches)])
    key_by_rid = {r.rid: k for r, k in zip(reqs, keys)}

    # healthy jitted oracle first: chaos corrupts seal-window boxes in
    # flight, and the oracle must see the pristine requests
    legacy = PrivateInferenceServer(cfg, params, mode="origami",
                                    max_batch=per)
    want = {}
    for j in range(n_batches):
        for r in legacy.serve_batch(list(reqs[per * j:per * (j + 1)])):
            want[r.rid] = PrivateInferenceServer.client_open(
                key_by_rid[r.rid], r.box, (cfg.num_classes,))

    pool = DevicePool(2, health=DeviceHealthConfig(breaker_after=2,
                                                   breaker_cooldown=2))
    chaos = ChaosController(schedule)
    engine = ServingEngine(EngineConfig(max_batch=per, max_wait_ms=50.0))
    engine.register_model("vgg16", cfg, params, mode="origami",
                          devices=pool, shard="rows",
                          liveness=LivenessConfig(cold_timeout_s=2.0),
                          chaos=chaos)
    timeline = []
    try:
        for j in range(n_batches):
            futs = [engine.submit("vgg16", r)
                    for r in reqs[per * j:per * (j + 1)]]
            resps = [f.result(timeout=120) for f in futs]
            degraded = engine.snapshot()["models"]["vgg16"]["degraded"]
            timeline.append((j, resps, degraded))
    finally:
        snap = engine.snapshot()
        engine.close()

    assert chaos.batch == n_batches - 1         # clock never drifted
    for j, resps, _ in timeline:
        for resp in resps:
            if j == 3:                          # the seal window
                assert not resp.ok and resp.error == "mac_failed", \
                    (j, resp)
            else:
                assert resp.ok and resp.error is None, (j, resp)
                got = PrivateInferenceServer.client_open(
                    key_by_rid[resp.rid], resp.box, (cfg.num_classes,))
                np.testing.assert_array_equal(got, want[resp.rid])

    liv = snap["liveness"]
    assert liv["degradations"] >= 1             # total blackout detected
    assert liv["recoveries"] >= 1               # ...and self-healed
    assert liv["shard_crashes"] >= 1 and liv["shard_timeouts"] >= 1
    assert not snap["models"]["vgg16"]["degraded"]
    assert any(d for _, _, d in timeline)       # degradation was observed
    assert not timeline[-1][2]                  # ...and cleared by the end
    slots = snap["devices"]["vgg16"]["pool"]["slots"]
    assert all(s["available"] for s in slots)   # both devices re-admitted
    assert all(s["breaker"] == BREAKER_CLOSED for s in slots)
    assert all(s["breaker_opens"] >= 1 for s in slots)
    # liveness is NOT an integrity indictment: no quarantine ever fired
    assert all(not s["quarantined"] for s in slots)


# ---------------------------------------------------------------------------
# draining shutdown: every in-flight future resolves, no orphaned threads
# ---------------------------------------------------------------------------

_OWNED_PREFIXES = ("offload-dev", "session-pool-refill",
                   "serving-engine-batcher")


def _owned_threads():
    return [t for t in threading.enumerate()
            if t.is_alive() and t.name.startswith(_OWNED_PREFIXES)]


def test_close_drains_in_flight_sharded_batches(vgg, rng):
    cfg, params = vgg
    before = {id(t) for t in _owned_threads()}
    pool = DevicePool(2)
    engine = ServingEngine(EngineConfig(max_batch=2, max_wait_ms=20.0))
    engine.register_model("vgg16", cfg, params, mode="origami",
                          devices=pool, shard="rows")
    reqs = [_request(cfg, 100 + i, rng)[0] for i in range(6)]
    futures = [engine.submit("vgg16", r) for r in reqs]
    engine.close()                              # immediately: work in flight

    for f in futures:                           # EVERY future resolved...
        assert f.done()
        resp = f.result(timeout=0)
        assert resp.ok or resp.error == "shutdown", resp
    assert any(f.result(timeout=0).ok for f in futures)  # ...and drained
    snap = engine.stats.snapshot(engine)
    assert snap["completed"] + snap["liveness"]["shutdown_drops"] \
        >= len(reqs)

    deadline = time.monotonic() + 10            # workers unwind quickly
    while time.monotonic() < deadline:
        orphans = [t for t in _owned_threads() if id(t) not in before]
        if not orphans:
            break
        time.sleep(0.05)
    assert not orphans, f"orphaned threads after close: {orphans}"

    # close is idempotent and late submits are rejected, not hung
    engine.close()
    late = engine.submit("vgg16", _request(cfg, 999, rng)[0])
    resp = late.result(timeout=5)
    assert not resp.ok and resp.error == "shutdown"
