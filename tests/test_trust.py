"""Cost-model plane (DESIGN.md §14 + paper Figs 9/10): the paper-constant
EnclaveSim strategy table is pinned to the published speedups, the new
``dispatch_overhead_s`` knob defaults to a bit-identical no-op, and
``CalibratedCostModel`` recovers known unit costs exactly and re-prices
``PartitionPlanner`` plans."""
import numpy as np
import pytest

from repro.configs import get_config, get_smoke
from repro.core import plan as PL
from repro.core.planner import PartitionPlanner
from repro.core.trust import (CalibratedCostModel, EnclaveParams, EnclaveSim,
                              vgg_layer_profiles)

# paper Fig 9/10 (GPU) and 12/13 (CPU) speedups vs the enclave baseline.
# The model derives runtimes from our layers' actual FLOP/byte profiles,
# so the pins are tolerance bands, not equalities: the GPU table tracks
# the paper closely; the CPU table runs hot because the paper's CPU
# numbers fold in framework overheads the model deliberately omits.
_PAPER = {
    ("vgg16", "gpu"): {"slalom": 10.0, "origami": 12.7},
    ("vgg19", "gpu"): {"slalom": 11.0, "origami": 15.1},
    ("vgg16", "cpu"): {"slalom": 2.9, "origami": 3.9},
    ("vgg19", "cpu"): {"slalom": 2.9, "origami": 3.9},
}
_TOL = {"gpu": 0.15, "cpu": 0.40}


@pytest.mark.parametrize("arch,device",
                         sorted(_PAPER, key=lambda k: (k[0], k[1])))
def test_fig9_10_strategy_speedups_pin_paper(arch, device):
    cfg = get_config(arch)
    sim = EnclaveSim(cfg, device=device)
    cs = sim.all_strategies(cfg.origami.tier1_layers)
    base = cs["enclave"].runtime_s
    for mode, want in _PAPER[(arch, device)].items():
        got = base / cs[mode].runtime_s
        assert got == pytest.approx(want, rel=_TOL[device]), \
            f"{arch}/{device}/{mode}: modeled {got:.2f}x vs paper {want}x"
    # the structural ordering the paper's figures show, regardless of
    # absolute calibration: origami > slalom > split > enclave
    assert (cs["origami"].runtime_s < cs["slalom"].runtime_s
            < cs["split"].runtime_s < cs["enclave"].runtime_s)


def test_benchmark_module_pins_same_paper_table():
    import pathlib
    import sys
    root = pathlib.Path(__file__).resolve().parent.parent
    sys.path.insert(0, str(root))
    try:
        from benchmarks.paper_fig9_10 import PAPER_SPEEDUPS
    finally:
        sys.path.pop(0)
    assert PAPER_SPEEDUPS == _PAPER


def test_dispatch_overhead_defaults_to_paper_identity():
    """``dispatch_overhead_s`` defaults to 0.0 — every Fig 9/10 number is
    bit-identical to the pre-knob model; a positive value slows exactly
    the strategies that dispatch to the device."""
    assert EnclaveParams().dispatch_overhead_s == 0.0
    cfg = get_smoke("vgg16")
    p = cfg.origami.tier1_layers
    plain = EnclaveSim(cfg).all_strategies(p)
    zeroed = EnclaveSim(
        cfg, params=EnclaveParams(dispatch_overhead_s=0.0)).all_strategies(p)
    for mode in plain:
        assert plain[mode].runtime_s == zeroed[mode].runtime_s
    taxed = EnclaveSim(
        cfg, params=EnclaveParams(dispatch_overhead_s=0.01)).all_strategies(p)
    assert taxed["enclave"].runtime_s == plain["enclave"].runtime_s
    n_lin = sum(1 for l in vgg_layer_profiles(cfg) if l.linear)
    assert taxed["slalom"].runtime_s == pytest.approx(
        plain["slalom"].runtime_s + 0.01 * n_lin)
    assert taxed["origami"].runtime_s > plain["origami"].runtime_s


def test_plan_quantities_match_layer_profiles():
    cfg = get_smoke("vgg16")
    sim = EnclaveSim(cfg)
    L = sim.layers
    lin = [l for l in L if l.linear]
    q = sim._plan_quantities(PL.from_string(cfg, "b" * len(L)))
    assert q["device_flops"] == sum(l.flops for l in lin)
    assert q["dispatches"] == len(lin)
    assert q["blind_bytes"] == q["unblind_bytes"] \
        == 2 * sum(l.out_bytes for l in lin)
    q = sim._plan_quantities(PL.from_string(cfg, "e" * len(L)))
    assert q["enclave_flops"] == sum(l.flops for l in L)
    assert q["device_flops"] == q["dispatches"] == 0.0


# -- CalibratedCostModel ----------------------------------------------------

_COSTS = {"device_flops": 2.5e-12, "blind_bytes": 4.0e-10,
          "unblind_bytes": 8.0e-10, "dispatches": 3.0e-3}


def _synthetic_obs(scale: float):
    quantities = {"device_flops": 1e9 * scale, "blind_bytes": 1e6 * scale,
                  "unblind_bytes": 1e6 * scale, "dispatches": 8.0 * scale}
    seconds = {phase: _COSTS[feat] * quantities[feat]
               for phase, feat in CalibratedCostModel.PHASE_FEATURES.items()
               if feat in _COSTS}
    return quantities, seconds


def test_fit_recovers_linear_costs_exactly():
    m = CalibratedCostModel(device="gpu")
    m.observe_all([_synthetic_obs(s) for s in (0.5, 1.0, 2.0)])
    assert m.n_observations == 3
    for feat, want in _COSTS.items():
        assert m.unit_costs[feat] == pytest.approx(want, rel=1e-12)
    fitted = m.fit()
    assert fitted.cpu_flops == pytest.approx(
        (1.0 / _COSTS["device_flops"]) / m.base.gpu_speedup)
    assert fitted.blind_bytes_per_s == pytest.approx(
        1.0 / _COSTS["blind_bytes"])
    assert fitted.enclave_mem_bytes_per_s == pytest.approx(
        1.0 / _COSTS["unblind_bytes"])
    assert fitted.dispatch_overhead_s == pytest.approx(_COSTS["dispatches"])
    # the paper ratios are held fixed — only the absolute scale moved
    assert fitted.gpu_speedup == m.base.gpu_speedup
    assert fitted.sgx_slowdown == m.base.sgx_slowdown
    # cpu device: the measured throughput IS cpu_flops
    mc = CalibratedCostModel(device="cpu")
    mc.observe_all([_synthetic_obs(1.0)])
    assert mc.fit().cpu_flops == pytest.approx(1.0 / _COSTS["device_flops"])


def test_fit_averages_noise_toward_truth():
    m = CalibratedCostModel()
    rng = np.random.default_rng(0)
    for s in rng.uniform(0.5, 2.0, size=64):
        quantities, seconds = _synthetic_obs(float(s))
        noisy = {p: t * float(rng.uniform(0.9, 1.1))
                 for p, t in seconds.items()}
        m.observe(quantities, noisy)
    for feat, want in _COSTS.items():
        assert m.unit_costs[feat] == pytest.approx(want, rel=0.1)


def test_unmeasured_features_keep_paper_values():
    m = CalibratedCostModel()
    m.observe({"device_flops": 0.0, "blind_bytes": 1e6},
              {"device_compute": 1.0, "blind": 0.0})
    assert m.unit_costs == {}                 # q=0 or t=0 never enter
    fitted = m.fit()
    assert fitted == m.base                   # nothing measured, no change
    g = m.gauges()
    assert g == {"costmodel.observations": 1.0}


def test_predict_plan_identity_without_observations():
    cfg = get_smoke("vgg16")
    sim = EnclaveSim(cfg)
    plan = PL.from_string(cfg, "b" * len(sim.layers))
    m = CalibratedCostModel(base=sim.p, device="gpu")
    assert m.predict_plan_s(sim, plan) == pytest.approx(
        sim.plan_runtime(plan).runtime_s)


def test_planner_calibrate_accepts_all_three_sources():
    planner = PartitionPlanner(device="gpu")
    assert planner.enclave_params is None     # paper constants in force

    explicit = EnclaveParams(cpu_flops=5e10)
    assert planner.calibrate(explicit) is explicit
    assert planner.enclave_params.cpu_flops == 5e10

    model = CalibratedCostModel(device="gpu")
    model.observe_all([_synthetic_obs(1.0)])
    got = planner.calibrate(model)
    assert got.cpu_flops == pytest.approx(
        (1.0 / _COSTS["device_flops"]) / model.base.gpu_speedup)

    class StubProfiler:
        def cost_observations(self):
            return [_synthetic_obs(1.0), _synthetic_obs(2.0)]

    got = planner.calibrate(StubProfiler())
    assert got.dispatch_overhead_s == pytest.approx(_COSTS["dispatches"])
    # calibrated params flow into subsequent pricing
    assert planner._sim(get_smoke("vgg16")).p is got
