"""Per-arch smoke tests (assignment requirement): reduced same-family
configs, one forward + one train step on CPU, shape and finiteness checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke
from repro.configs.base import TrainConfig
from repro.launch.steps import make_train_step
from repro.models import model as M
from repro.optim import adamw


def _batch(cfg, B=2, S=16, seed=0):
    k = jax.random.PRNGKey(seed)
    batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            k, (B, cfg.encoder_seq_len, cfg.d_model), jnp.float32) * 0.1
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            k, (B, cfg.vision_seq_len, cfg.d_model), jnp.float32) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    out = jax.jit(lambda p, b: M.forward(p, b, cfg))(params, _batch(cfg, B, S))
    lg = np.asarray(out.logits, np.float32)
    assert lg.shape == (B, S, cfg.padded_vocab)
    assert np.isfinite(lg).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = get_smoke(arch)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=1, total_steps=10)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(params, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    p2, o2, metrics = step(params, opt, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(p2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_all_archs(arch):
    cfg = get_smoke(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 8
    caches = M.init_caches(cfg, B, S)
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0,
                             cfg.vocab_size)
    logits, c2 = jax.jit(
        lambda p, t, c: M.decode_step(p, t, c, jnp.int32(0), cfg))(
        params, tok, caches)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert jax.tree.structure(c2) == jax.tree.structure(caches)


@pytest.mark.parametrize("arch", ARCHS + ["vgg16", "vgg19"])
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact published dimensions."""
    cfg = get_config(arch)
    expect = {
        "qwen2_5_14b": (48, 5120, 40, 8, 13824, 152064),
        "yi_9b": (48, 4096, 32, 4, 11008, 64000),
        "minicpm3_4b": (62, 2560, 40, 40, 6400, 73448),
        "smollm_135m": (30, 576, 9, 3, 1536, 49152),
        "qwen3_moe_235b": (94, 4096, 64, 4, 1536, 151936),
        "arctic_480b": (35, 7168, 56, 8, 4864, 32000),
        "zamba2_1_2b": (38, 2048, 32, 32, 8192, 32000),
        "whisper_small": (12, 768, 12, 12, 3072, 51865),
        "llama3_2_vision_11b": (40, 4096, 32, 8, 14336, 128256),
        "xlstm_1_3b": (48, 2048, 4, 4, 0, 50304),
    }
    if arch in expect:
        L, d, h, kv, ff, v = expect[arch]
        assert cfg.num_layers == L and cfg.d_model == d
        assert cfg.num_heads == h and cfg.num_kv_heads == kv
        assert cfg.d_ff == ff and cfg.vocab_size == v
    else:
        assert cfg.family == "cnn" and cfg.image_size == 224


def test_moe_active_params():
    cfg = get_config("qwen3_moe_235b")
    total = M.count_params_analytic(cfg)
    active = M.active_params_analytic(cfg)
    assert 230e9 < total < 240e9            # "235B"
    assert 20e9 < active < 24e9             # "A22B"


def test_loss_decreases_quickly_on_tiny_model():
    cfg = get_smoke("smollm_135m")
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=2, total_steps=30)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(params, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = _batch(cfg, B=4, S=32, seed=3)  # overfit one batch
    losses = []
    for _ in range(15):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses
