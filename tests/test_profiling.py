"""Performance-attribution plane (DESIGN.md §14): critical-path phase
folding (exact wall decomposition, compile isolation, parallel-child
interval merging), the flight recorder's redaction-enforced post-mortem
bundles (including a live quarantine-triggered bundle byte-scanned for
the run's secrets), and the bench_check regression gate."""
import json
import pathlib
import re
import sys

import numpy as np
import pytest

from repro.core.tracing import RedactionError, Span, Tracer
from repro.runtime.profiling import (PHASES, CriticalPathProfiler,
                                     FlightRecorder, _merge_intervals,
                                     phase_of)

SENTINEL = 0.91827364  # seeds the quarantine drill's plaintext input


class FakeTracer:
    """Hand-built span store — ``ingest`` only needs ``spans()``."""

    def __init__(self, spans):
        self._spans = list(spans)
        self.dropped = 0

    def spans(self):
        return list(self._spans)


def _span(sid, parent, name, t0, t1, kind="step", **attrs):
    return Span(trace_id=1, span_id=sid, parent_id=parent, name=name,
                kind=kind, t0=t0, t1=t1, attrs=attrs)


def _tree(rid=1, model="m", plan="abc", t0=0.0, infer_dur=1.0,
          first_call=False, base_sid=0, flops=1000):
    """request(4s wall) -> queue(1s) + batch -> unseal(0.5) + infer + seal.

    Laid out with known gaps so every phase's expected critical seconds
    are hand-computable.
    """
    sid = base_sid
    spans = [
        _span(sid + 1, None, "request", t0, t0 + 4.0, model=model,
              plan=plan, shape=[8, 8, 3], rid=rid),
        _span(sid + 2, sid + 1, "queue", t0, t0 + 1.0),
        _span(sid + 3, sid + 1, "batch", t0 + 1.0, t0 + 3.5, plan=plan),
        _span(sid + 4, sid + 3, "unseal", t0 + 1.0, t0 + 1.5),
        _span(sid + 5, sid + 3, "infer", t0 + 1.5,
              t0 + 1.5 + infer_dur, first_call=first_call,
              device_flops=flops, blind_bytes=64, unblind_bytes=32),
        _span(sid + 6, sid + 3, "seal", t0 + 3.2, t0 + 3.5),
    ]
    return spans


def test_phase_taxonomy_is_total():
    for name in ("queue", "unseal", "seal", "session.acquire",
                 "kernel.blind_encode", "kernel.fused_blind_matmul",
                 "kernel.limb_matmul", "kernel.unblind", "kernel.fold",
                 "op.blinded", "op.trusted", "shard.matmul",
                 "shard.dispatch", "shard.enclave", "infer",
                 "plan.segment", "verify", "batch", "request"):
        assert phase_of(name) in PHASES
    assert phase_of("some.future.span") == "other"   # never drops time


def test_merge_intervals():
    assert _merge_intervals([]) == []
    assert _merge_intervals([(0, 1), (2, 3)]) == [(0, 1), (2, 3)]
    assert _merge_intervals([(0, 2), (1, 3), (2.5, 4)]) == [(0, 4)]
    assert _merge_intervals([(1, 2), (0, 5)]) == [(0, 5)]


def test_fold_attributes_every_instant_exactly_once():
    prof = CriticalPathProfiler()
    assert prof.ingest(FakeTracer(_tree())) == 1
    (key, p), = prof.profiles.items()
    assert key == ("m", "abc", "8x8x3")
    crit = p.critical_s
    # hand-computed: queue 1.0; unseal 0.5; infer 1.0 (device_compute);
    # seal 0.3; batch self = 2.5 - (0.5 + 1.0 + 0.3) = 0.7 (other);
    # request self = 4.0 - (1.0 + 2.5) = 0.5 (other)
    assert crit["queue_wait"] == pytest.approx(1.0)
    assert crit["unseal"] == pytest.approx(0.5)
    assert crit["device_compute"] == pytest.approx(1.0)
    assert crit["seal"] == pytest.approx(0.3)
    assert crit["other"] == pytest.approx(1.2)
    # THE invariant: per-phase criticals sum to the request wall exactly
    assert sum(crit.values()) == pytest.approx(p.wall_s) == pytest.approx(4.0)
    # ingest is incremental: same store again folds nothing new
    assert prof.ingest(FakeTracer(_tree())) == 0


def test_parallel_children_do_not_double_claim():
    """Two overlapping shard dispatches under one shard.matmul: critical
    charges the covered extent once; total charges both durations."""
    spans = [
        _span(1, None, "request", 0.0, 3.0, model="m", plan="d",
              shape=[4]),
        _span(2, 1, "shard.matmul", 0.0, 3.0),
        _span(3, 2, "shard.dispatch", 0.5, 2.0),
        _span(4, 2, "shard.dispatch", 1.0, 2.5),   # overlaps [1.0, 2.0]
    ]
    prof = CriticalPathProfiler()
    prof.ingest(FakeTracer(spans))
    p = prof.profiles[("m", "d", "4")]
    # dispatches cover [0.5, 2.5] -> matmul self (dispatch_wait) = 1.0
    assert p.critical_s["dispatch_wait"] == pytest.approx(1.0)
    assert p.critical_s["device_compute"] == pytest.approx(2.0)
    assert p.total_s["device_compute"] == pytest.approx(1.5 + 1.5)
    assert sum(p.critical_s.values()) == pytest.approx(3.0)


def test_unfinished_and_non_request_roots_are_skipped():
    prof = CriticalPathProfiler()
    open_root = _span(1, None, "request", 0.0, None, model="m")
    stray = _span(2, None, "batch", 0.0, 1.0)
    assert prof.ingest(FakeTracer([open_root, stray])) == 0
    assert prof.ingest(None) == 0                 # engines without a tracer


def test_compile_isolation_first_call_minus_warm_median():
    prof = CriticalPathProfiler()
    spans = []
    # first call: infer takes 1.7s; three warm calls: 0.5s each
    spans += _tree(rid=1, infer_dur=1.7, first_call=True, base_sid=0)
    for i in range(3):
        spans += _tree(rid=2 + i, t0=10.0 * (i + 1), infer_dur=0.5,
                       base_sid=100 * (i + 1))
    prof.ingest(FakeTracer(spans))
    p = prof.profiles[("m", "abc", "8x8x3")]
    assert p.compile_s == pytest.approx(1.2)      # 1.7 - median(0.5)
    summ = p.summary()
    assert summ["compile_s"] == pytest.approx(1.2)
    # carved OUT of device_compute, and the sum-to-wall invariant holds
    assert summ["critical_s"]["compile"] == pytest.approx(1.2)
    assert summ["critical_s"]["device_compute"] == pytest.approx(
        1.7 + 3 * 0.5 - 1.2)
    assert summ["critical_sum_s"] == pytest.approx(summ["wall_s"])
    # report rolls the same numbers up
    rep = prof.report()
    assert rep["requests"] == 4
    assert rep["critical_s"]["compile"] == pytest.approx(1.2)


def test_cost_observations_warm_trees_only():
    prof = CriticalPathProfiler()
    spans = list(_tree(rid=1, infer_dur=2.0, first_call=True, flops=500))
    spans += _tree(rid=2, t0=10.0, infer_dur=0.5, base_sid=100, flops=500)
    prof.ingest(FakeTracer(spans))
    obs = prof.cost_observations()
    assert len(obs) == 1                          # first-call tree excluded
    quantities, seconds = obs[0]
    assert quantities["device_flops"] == 500
    assert quantities["blind_bytes"] == 64
    assert seconds["device_compute"] == pytest.approx(0.5)


def test_export_gauges():
    from repro.runtime.observability import MetricsRegistry
    prof = CriticalPathProfiler()
    prof.ingest(FakeTracer(_tree()))
    reg = MetricsRegistry()
    prof.export_gauges(reg)
    g = reg.snapshot()["gauges"]
    assert g["phase.requests"] == 1
    assert g["phase.queue_wait_s"] == pytest.approx(1.0)


# -- flight recorder -------------------------------------------------------

def test_flight_recorder_events_and_dump(tmp_path):
    rec = FlightRecorder(capacity=4, out_dir=str(tmp_path),
                         min_interval_s=0.0)
    for i in range(6):
        rec.event("shard_crash", device="dev0", i=i)
    assert len(rec.events) == 4                   # bounded ring
    tr = Tracer()
    s = tr.start_span("request", "request", model="m")
    tr.end(s)
    from repro.runtime.observability import MetricsRegistry
    reg = MetricsRegistry()
    reg.inc("integrity.quarantines")
    b1 = rec.dump("quarantine", tracer=tr, registry=reg, model="m")
    assert b1["trigger"] == "quarantine"
    assert [e["attrs"]["i"] for e in b1["events"]] == [2, 3, 4, 5]
    assert b1["spans"][0]["name"] == "request"
    assert b1["metrics"]["counter_delta"] == {"integrity.quarantines": 1}
    # second dump reports only the delta since the first
    reg.inc("integrity.quarantines", 2)
    b2 = rec.dump("quarantine", tracer=tr, registry=reg)
    assert b2["metrics"]["counter_delta"] == {"integrity.quarantines": 2}
    files = sorted(tmp_path.glob("postmortem_*.json"))
    assert [f.name for f in files] == ["postmortem_000_quarantine.json",
                                       "postmortem_001_quarantine.json"]
    assert json.loads(files[0].read_text())["trigger"] == "quarantine"
    assert rec.snapshot()["dumps"] == 2


def test_flight_recorder_rate_limits_per_trigger():
    rec = FlightRecorder(min_interval_s=3600.0)
    assert rec.dump("verify_failure") is not None
    assert rec.dump("verify_failure") is None     # same kind: suppressed
    assert rec.dump("degradation") is not None    # other kind: allowed
    assert rec.suppressed == 1


def test_flight_recorder_redaction_fails_closed():
    rec = FlightRecorder()
    with pytest.raises(RedactionError):
        rec.event("oops", payload=np.arange(8))
    assert len(rec.events) == 0
    with pytest.raises(RedactionError):
        rec.dump("manual", secret=b"key")


def test_flight_recorder_caps_disk_dumps(tmp_path):
    rec = FlightRecorder(out_dir=str(tmp_path), min_interval_s=0.0,
                         max_dumps=2)
    for _ in range(4):
        rec.dump("manual")
    assert len(list(tmp_path.glob("*.json"))) == 2
    assert rec.snapshot()["dumps"] == 4           # ring keeps counting


# -- the acceptance drill: injected quarantine -> redacted bundle ----------

@pytest.fixture(scope="module")
def quarantine_bundle(tmp_path_factory):
    """A dishonest device flips bits under full verification with
    ``quarantine_after=1`` — the first flagged batch must quarantine the
    model AND dump a post-mortem bundle; the bundle is byte-scanned for
    the run's actual secrets (sentinel-seeded input, session keys,
    logits)."""
    import jax
    from repro.configs import get_smoke
    from repro.core.integrity import IntegrityPolicy
    from repro.models import model as M
    from repro.runtime.engine import EngineConfig, ServingEngine
    from repro.runtime.faults import DishonestDevice, FaultSpec
    from repro.runtime.serving import PrivateInferenceServer, Request

    out_dir = tmp_path_factory.mktemp("postmortem")
    cfg = get_smoke("vgg16")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tracer = Tracer(kernel_spans=False)
    rec = FlightRecorder(out_dir=str(out_dir), min_interval_s=0.0)
    engine = ServingEngine(
        EngineConfig(max_batch=2, max_wait_ms=20.0, quarantine_after=1),
        tracer=tracer, recorder=rec)
    entry = engine.register_model(
        "vgg16", cfg, params, mode="origami",
        integrity=IntegrityPolicy.full(1),
        fault=DishonestDevice(FaultSpec("bit_flip")))
    img = np.full((cfg.image_size, cfg.image_size, 3), SENTINEL,
                  np.float32)
    key = np.array([0xFEEDC0DE, 0x87654321], dtype=np.uint32)
    box = PrivateInferenceServer.client_seal(key, img, 3)
    resp = engine.submit("vgg16", Request(
        rid=3, box=box, shape=img.shape, session_key=key)).result(
        timeout=300)
    assert resp.ok, resp.error
    logits = PrivateInferenceServer.client_open(key, resp.box,
                                                (cfg.num_classes,))
    snap = engine.snapshot()
    engine.close()
    return {"snap": snap, "entry": entry, "out_dir": out_dir,
            "recorder": rec, "img": img, "key": key, "logits": logits}


def test_quarantine_dumps_postmortem_bundle(quarantine_bundle):
    snap = quarantine_bundle["snap"]
    assert snap["models"]["vgg16"]["quarantined"]
    assert snap["integrity"]["quarantines"] == 1
    names = [f.name for f in
             sorted(quarantine_bundle["out_dir"].glob("*.json"))]
    assert any("quarantine" in n for n in names), names
    assert any("verify_failure" in n for n in names), names
    bundle = quarantine_bundle["recorder"].last_bundle
    assert bundle["trigger"] in ("quarantine", "verify_failure")
    assert bundle["metrics"]["counter_delta"]
    assert any(s["name"] == "request" for s in bundle["spans"])
    # the engine also exports the recorder state in its snapshot
    assert snap["flight_recorder"]["dumps"] == len(names)


def test_postmortem_bundle_carries_no_secret_material(quarantine_bundle):
    """PR 7 byte-scan contract extended to post-mortem bundles: the files
    CI uploads must structurally exclude client inputs, key material and
    logits (redaction already rejects arrays; this catches any future
    text-smuggle path too)."""
    blobs = [(f.name, f.read_text()) for f in
             sorted(quarantine_bundle["out_dir"].glob("*.json"))]
    assert blobs
    key = quarantine_bundle["key"]
    forbidden_text = [f"{SENTINEL:.8f}"[:9]]
    forbidden_text += [str(int(w)) for w in key if int(w) > 10 ** 6]
    for v in np.asarray(quarantine_bundle["logits"]).ravel():
        if abs(v) > 1e-3:
            forbidden_text.append(np.format_float_positional(
                v, precision=6, trim="-"))
    for name, text in blobs:
        raw = text.encode()
        assert key.tobytes() not in raw
        assert quarantine_bundle["img"].tobytes()[:4096] not in raw
        for ft in forbidden_text:
            pat = re.compile(rf"(?<![\d.]){re.escape(ft)}(?![\d.])")
            assert not pat.search(text), \
                f"secret {ft!r} leaked into {name}"


def test_engine_snapshot_phases_decompose_wall(quarantine_bundle):
    """The tentpole surface: snapshot()["phases"] decomposes the traced
    round with compile isolated and criticals summing to wall."""
    phases = quarantine_bundle["snap"]["phases"]
    assert phases["requests"] == 1
    assert set(phases["taxonomy"]) == set(PHASES)
    (key, prof), = phases["profiles"].items()
    model, digest, bucket = key.split("|")
    assert model == "vgg16"
    assert digest == quarantine_bundle["entry"].executor.plan.digest[:12]
    assert prof["critical_sum_s"] == pytest.approx(prof["wall_s"],
                                                   rel=1e-6)
    assert prof["critical_s"]["unseal"] > 0
    assert prof["critical_s"]["seal"] > 0


# -- bench_check regression gate -------------------------------------------

def _bench_check():
    root = pathlib.Path(__file__).resolve().parent.parent
    sys.path.insert(0, str(root / "scripts"))
    try:
        import bench_check
    finally:
        sys.path.pop(0)
    return bench_check


def test_bench_check_direction_bands():
    bc = _bench_check()
    # lower-is-better: regression only above base*(1+rel)+abs
    assert bc.check_metric(100.0, 150.0, "lower", 0.6, 0.0)
    assert not bc.check_metric(100.0, 161.0, "lower", 0.6, 0.0)
    assert bc.check_metric(1.0, 5.0, "lower", 0.0, 4.0)
    # higher-is-better: regression only below base*(1-rel)-abs
    assert bc.check_metric(10.0, 6.0, "higher", 0.5, 0.0)
    assert not bc.check_metric(10.0, 4.0, "higher", 0.5, 0.0)
    assert bc.check_metric(1.0, 1.0, "higher", 0.0, 0.0)  # exact pin holds
    assert not bc.check_metric(1.0, 0.99, "higher", 0.0, 0.0)


def test_bench_check_passes_committed_baselines():
    """The committed baselines must gate green against the committed
    fresh artifacts (they are seeded from them)."""
    bc = _bench_check()
    root = pathlib.Path(__file__).resolve().parent.parent
    base_dir = root / "benchmarks" / "baselines"
    assert base_dir.is_dir(), "benchmarks/baselines/ missing"
    fails = []
    for suite, fname in bc.FILES.items():
        base, fresh = base_dir / fname, root / fname
        if not base.exists() or not fresh.exists():
            continue
        fails += bc.check_suite(suite, json.loads(base.read_text()),
                                json.loads(fresh.read_text()))
    assert fails == []


def test_bench_check_fails_synthetic_regression(tmp_path):
    bc = _bench_check()
    base = {"results": {"load_burst": {"achieved_rps": 6.0},
                        "engine": {"time_to_first_batch_s": 8.0}}}
    # 10x throughput collapse: outside the 0.6 rel band
    regressed = {"results": {"load_burst": {"achieved_rps": 0.6},
                             "engine": {"time_to_first_batch_s": 8.0}}}
    fails = bc.check_suite("serving", base, regressed)
    assert len(fails) == 1 and "achieved_rps" in fails[0]
    # a vanished metric fails loudly too
    gone = {"results": {"engine": {"time_to_first_batch_s": 8.0}}}
    fails = bc.check_suite("serving", base, gone)
    assert any("missing" in f for f in fails)
    # and the same docs inside the band pass
    assert bc.check_suite("serving", base, base) == []
