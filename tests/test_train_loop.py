"""End-to-end train loop: convergence, bitwise resume, microbatch equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import TrainConfig
from repro.launch.steps import make_train_step
from repro.launch.train import train
from repro.models import model as M
from repro.optim import adamw


def test_smollm_loss_decreases(tmp_path):
    cfg = get_smoke("smollm_135m")
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=5, total_steps=30)
    _, _, losses = train(cfg, tcfg, batch=4, seq=64, steps=30,
                         ckpt_dir=None, log_every=0)
    assert losses[-1] < losses[0] - 0.2, (losses[0], losses[-1])


def test_resume_is_bitwise(tmp_path):
    cfg = get_smoke("smollm_135m")
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=10)
    # run 10 straight
    pA, oA, _ = train(cfg, tcfg, batch=2, seq=32, steps=10, ckpt_dir=None,
                      log_every=0)
    # run 5, checkpoint, resume to 10
    d = tmp_path / "ck"
    train(cfg, tcfg, batch=2, seq=32, steps=5, ckpt_dir=str(d),
          ckpt_every=5, log_every=0)
    pB, oB, _ = train(cfg, tcfg, batch=2, seq=32, steps=10,
                      ckpt_dir=str(d), ckpt_every=100, log_every=0)
    for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pB)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_microbatched_grads_match_full_batch():
    cfg = get_smoke("yi_9b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                          cfg.vocab_size)}
    outs = {}
    for m in (1, 2, 4):
        tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=1,
                           total_steps=10, microbatches=m)
        opt = adamw.init(params, tcfg)
        p2, _, metrics = jax.jit(make_train_step(cfg, tcfg))(params, opt,
                                                             batch)
        outs[m] = (jax.tree.leaves(p2), float(metrics["loss"]))
    for m in (2, 4):
        assert abs(outs[m][1] - outs[1][1]) < 5e-2
        for a, b in zip(outs[1][0], outs[m][0]):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=0.1, atol=2e-2)


def test_optimizer_bf16_moments_close_to_fp32():
    cfg = get_smoke("smollm_135m")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                          cfg.vocab_size)}
    results = {}
    for dt in ("float32", "bfloat16"):
        tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=1,
                           total_steps=10, moment_dtype=dt)
        opt = adamw.init(params, tcfg)
        step = jax.jit(make_train_step(cfg, tcfg))
        p, o = params, opt
        for _ in range(3):
            p, o, m = step(p, o, batch)
        results[dt] = float(m["loss"])
    assert abs(results["bfloat16"] - results["float32"]) < 0.05
