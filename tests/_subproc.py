"""Helper: run a python snippet in a subprocess with N fake XLA devices.

Used by tests that need a multi-device mesh without polluting the main
test process (which must keep exactly 1 device for smoke tests).
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")


def run_with_devices(code: str, n_devices: int = 8,
                     timeout: int = 300) -> subprocess.CompletedProcess:
    prelude = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = "
        f"'--xla_force_host_platform_device_count={n_devices}'\n"
        "import sys\n"
        f"sys.path.insert(0, {SRC!r})\n"
    )
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-c", prelude + textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)


def check(code: str, n_devices: int = 8, timeout: int = 300) -> str:
    r = run_with_devices(code, n_devices, timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout
