"""HLO parser: loop trip counts, dot FLOPs, collective bytes (subprocess
tests with a multi-device mesh; known-answer validation)."""
import pytest

from repro.parallel.hlo_analysis import _shape_bytes, _shape_dims, analyze_hlo
from tests._subproc import check


def test_shape_bytes_parsing():
    assert _shape_bytes("f32[32,128]{1,0}") == 32 * 128 * 4
    assert _shape_bytes("bf16[8]") == 16
    assert _shape_bytes("(f32[4,4], s32[2])") == 64 + 8
    assert _shape_bytes("pred[]") == 1      # scalar = one element
    assert _shape_dims("bf16[2,3,4]{2,1,0}") == [2, 3, 4]


SCAN_PROG = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.parallel.hlo_analysis import analyze_hlo
from repro.launch.mesh import _axis_types_kwargs
mesh = jax.make_mesh((2, 4), ("data", "model"), **_axis_types_kwargs(2))
D, L, B = 128, 6, 64
def f(x, ws):
    def body(c, w):
        y = c @ w
        return jax.lax.with_sharding_constraint(
            y, NamedSharding(mesh, P("data", "model"))), None
    y, _ = jax.lax.scan(body, x, ws)
    return y.sum()
xs = jax.ShapeDtypeStruct((B, D), jnp.float32)
ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
with mesh:
    c = jax.jit(f, in_shardings=(
        NamedSharding(mesh, P("data", None)),
        NamedSharding(mesh, P(None, None, "model")))).lower(xs, ws).compile()
st = analyze_hlo(c.as_text())
print("TRIPS", st.trip_counts)
print("FLOPS", st.dot_flops)
print("EXPECTED", 2 * B * D * D * L / 8)
print("COLL", sorted(st.bytes_by_kind))
"""


@pytest.mark.slow
def test_scan_flops_and_trips_exact():
    out = check(SCAN_PROG, n_devices=8)
    lines = dict(l.split(" ", 1) for l in out.strip().splitlines())
    assert lines["TRIPS"] == "[6]"
    assert float(lines["FLOPS"]) == float(lines["EXPECTED"])
    assert "all-gather" in lines["COLL"] or "all-reduce" in lines["COLL"]


def test_analyze_empty():
    st = analyze_hlo("")
    assert st.dot_flops == 0 and st.total_collective_bytes == 0
