"""SSM invariants: chunked recurrence == sequential oracle; decode == slice."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke
from repro.models import model as M
from repro.models import ssm as S


def _naive_recurrence(q, k, v, log_a, b, normalize=False, den_floor=None):
    """Sequential oracle for chunked_linear_recurrence."""
    B, T, H, dk = q.shape
    dv = v.shape[-1]
    C = np.zeros((B, H, dk, dv), np.float64)
    n = np.zeros((B, H, dk), np.float64)
    ys = np.zeros((B, T, H, dv), np.float64)
    dens = np.zeros((B, T, H), np.float64)
    a = np.exp(np.asarray(log_a, np.float64))
    for t in range(T):
        C = a[:, t, :, None, None] * C + b[:, t, :, None, None] * \
            np.einsum("bhd,bhe->bhde", k[:, t], v[:, t])
        n = a[:, t, :, None] * n + b[:, t, :, None] * k[:, t]
        ys[:, t] = np.einsum("bhd,bhde->bhe", q[:, t], C)
        dens[:, t] = np.einsum("bhd,bhd->bh", q[:, t], n)
    if normalize:
        floor = den_floor if den_floor is not None else 1e-6
        ys = ys / np.maximum(np.abs(dens), floor)[..., None]
    return ys


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 3), st.integers(1, 4), st.sampled_from([8, 16, 32]),
       st.integers(0, 2 ** 31 - 1))
def test_chunked_recurrence_matches_sequential(Bsz, H, T, seed):
    rng = np.random.default_rng(seed)
    dk, dv, chunk = 4, 6, 8
    q = rng.normal(size=(Bsz, T, H, dk)).astype(np.float32)
    k = rng.normal(size=(Bsz, T, H, dk)).astype(np.float32)
    v = rng.normal(size=(Bsz, T, H, dv)).astype(np.float32)
    log_a = -np.abs(rng.normal(size=(Bsz, T, H))).astype(np.float32)
    b = np.abs(rng.normal(size=(Bsz, T, H))).astype(np.float32)
    got, (Cf, nf) = S.chunked_linear_recurrence(
        *map(jnp.asarray, (q, k, v, log_a, b)), chunk=chunk)
    want = _naive_recurrence(q, k, v, log_a, b)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-3)


def test_chunked_recurrence_normalized(rng):
    Bsz, T, H, dk, dv = 2, 32, 2, 4, 4
    q = rng.normal(size=(Bsz, T, H, dk)).astype(np.float32)
    k = rng.normal(size=(Bsz, T, H, dk)).astype(np.float32)
    v = rng.normal(size=(Bsz, T, H, dv)).astype(np.float32)
    log_a = -np.abs(rng.normal(size=(Bsz, T, H))).astype(np.float32) * 0.1
    b = np.abs(rng.normal(size=(Bsz, T, H))).astype(np.float32)
    got, _ = S.chunked_linear_recurrence(
        *map(jnp.asarray, (q, k, v, log_a, b)), chunk=8, normalize=True)
    want = _naive_recurrence(q, k, v, log_a, b, normalize=True)
    np.testing.assert_allclose(np.asarray(got), want, rtol=5e-3, atol=5e-3)


def test_final_state_consistency(rng):
    """Final carry equals running the step function T times."""
    Bsz, T, H, dk, dv = 1, 16, 2, 4, 4
    args = [rng.normal(size=(Bsz, T, H, d)).astype(np.float32)
            for d in (dk, dk, dv)]
    log_a = -np.abs(rng.normal(size=(Bsz, T, H))).astype(np.float32)
    b = np.abs(rng.normal(size=(Bsz, T, H))).astype(np.float32)
    _, (Cf, nf) = S.chunked_linear_recurrence(
        *map(jnp.asarray, (*args, log_a, b)), chunk=8)
    state = (jnp.zeros((Bsz, H, dk, dv)), jnp.zeros((Bsz, H, dk)))
    for t in range(T):
        _, state = S.linear_recurrence_step(
            jnp.asarray(args[0][:, t]), jnp.asarray(args[1][:, t]),
            jnp.asarray(args[2][:, t]), jnp.exp(jnp.asarray(log_a[:, t])),
            jnp.asarray(b[:, t]), state)
    np.testing.assert_allclose(np.asarray(Cf), np.asarray(state[0]),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["zamba2_1_2b", "xlstm_1_3b"])
def test_ssm_decode_matches_forward(arch):
    """Teacher-forced forward logits == step-by-step decode logits."""
    cfg = get_smoke(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    Bsz, T = 1, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (Bsz, T), 0,
                                cfg.vocab_size)
    full = np.asarray(M.forward(params, {"tokens": tokens}, cfg).logits,
                      np.float32)
    caches = M.init_caches(cfg, Bsz, T)
    outs = []
    for t in range(T):
        logits, caches = M.decode_step(params, tokens[:, t:t + 1], caches,
                                       jnp.int32(t), cfg)
        outs.append(np.asarray(logits, np.float32)[:, 0])
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, full, rtol=0.06, atol=0.06)


def test_causal_conv_cache_consistency(rng):
    w = jnp.asarray(rng.normal(size=(4, 6)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 12, 6)), jnp.float32)
    full, _ = S.causal_conv1d(w, x)
    cache = jnp.zeros((2, 3, 6))
    outs = []
    for t in range(12):
        y, cache = S.causal_conv1d(w, x[:, t:t + 1], cache=cache)
        outs.append(y[:, 0])
    np.testing.assert_allclose(np.stack(outs, 1), np.asarray(full),
                               rtol=1e-5, atol=1e-5)
