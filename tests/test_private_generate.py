"""Private autoregressive decode (DESIGN.md §16): blinded ring-fed decode
vs the trusted enclave oracle, ring-vs-live in-trace parity, the jitted
recurrent prefill, and engine token-stream serving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import integrity as IG
from repro.models import model as M
from repro.runtime import generate as G
from repro.runtime.engine import EngineConfig, ServingEngine
from repro.runtime.serving import PrivateInferenceServer, Request


@pytest.fixture(scope="module")
def smollm():
    cfg = get_smoke("smollm_135m")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompt(cfg, batch=2, length=6, seed=1):
    return jax.random.randint(jax.random.PRNGKey(seed), (batch, length),
                              0, cfg.vocab_size)


def test_private_generate_bit_exact_vs_trusted_oracle(smollm):
    """The acceptance smoke: blinded KV-facing matmuls + per-step
    Freivalds on, logits AND tokens bit-exact vs trusted=True."""
    cfg, params = smollm
    prompt = _prompt(cfg)
    pol = IG.IntegrityPolicy.full(k=2)
    kw = dict(max_new_tokens=5, integrity=pol,
              session_key=jax.random.PRNGKey(9))
    priv = G.private_generate(params, prompt, cfg, **kw)
    oracle = G.private_generate(params, prompt, cfg, trusted=True, **kw)
    np.testing.assert_array_equal(np.asarray(priv.tokens),
                                  np.asarray(oracle.tokens))
    np.testing.assert_array_equal(np.asarray(priv.logits),
                                  np.asarray(oracle.logits))
    # the private run actually offloaded and verified
    assert priv.telemetry.device_matmuls > 0
    assert priv.telemetry.verify_ops > 0
    assert priv.integrity.n_ops > 0
    assert priv.integrity.n_checked == priv.integrity.n_ops
    assert priv.integrity.ok
    # the trusted oracle ran everything in the enclave
    assert oracle.telemetry.device_matmuls == 0
    assert oracle.telemetry.trusted_matmuls > 0
    assert oracle.ring is None
    # one ring slot consumed per decode step
    assert priv.ring["consumed"] == priv.decode_steps
    assert priv.plan_digest == oracle.plan_digest


def test_decode_once_ring_vs_live_factors_bit_exact(smollm):
    """One token step fed by a ring slot == the same step deriving its
    factors live in-trace — the end-to-end form of the cached-vs-live
    stream identity."""
    cfg, params = smollm
    from repro.core.origami import OrigamiExecutor
    from repro.runtime.sessions import TokenSlotRing
    pol = IG.IntegrityPolicy.full(k=2)
    ex = OrigamiExecutor(cfg, params, "origami", integrity=pol)
    ex.attach_decode_plan(max_steps=16)
    key = jax.random.PRNGKey(3)
    prompt = _prompt(cfg)
    S0 = prompt.shape[1]
    logits, caches, _ = ex.prefill_session(prompt, key,
                                           max_seq=S0 + 4)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    ring = TokenSlotRing(ex.decode_cache(prompt.shape[0]), key, lo=S0,
                         depth=2, background=False)
    try:
        y_ring, _, rep_ring = ex.decode_once(tok, caches, S0, key,
                                             ring.take(S0))
        y_live, _, rep_live = ex.decode_once(tok, caches, S0, key, None)
    finally:
        ring.close()
    np.testing.assert_array_equal(np.asarray(y_ring), np.asarray(y_live))
    assert rep_ring.n_checked == rep_live.n_checked > 0
    assert rep_ring.ok and rep_live.ok


def test_private_generate_detects_dishonest_device(smollm):
    """A corrupting device fails the per-step Freivalds folds."""
    cfg, params = smollm
    from repro.core.origami import OrigamiExecutor
    from repro.runtime.faults import DishonestDevice, FaultSpec
    prompt = _prompt(cfg)
    ex = OrigamiExecutor(cfg, params, "origami",
                         integrity=IG.IntegrityPolicy.full(k=2),
                         fault=DishonestDevice(FaultSpec("bit_flip")))
    res = G.private_generate(params, prompt, cfg, max_new_tokens=3,
                             session_key=jax.random.PRNGKey(4),
                             executor=ex)
    assert res.integrity.n_failed > 0
    assert not res.integrity.ok


def test_recurrent_prefill_jitted_matches_eager_loop():
    """Satellite: the fori_loop prompt prefill for recurrent families is
    bit-identical to the per-token eager loop it replaced."""
    cfg = get_smoke("zamba2_1_2b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S0, new = 2, 5, 3
    prompt = jax.random.randint(jax.random.PRNGKey(2), (B, S0), 0,
                                cfg.vocab_size)
    total = S0 + new
    # the replaced implementation, verbatim
    caches = M.init_caches(cfg, B, total)
    logits = None
    for t in range(S0):
        logits, caches = M.decode_step(params, prompt[:, t:t + 1], caches,
                                       jnp.int32(t), cfg)
    res = G.generate(params, prompt, cfg, max_new_tokens=new)
    assert res.tokens.shape == (B, total)
    # oracle continuation from the eager-prefill state
    tokens = jnp.concatenate(
        [prompt, jnp.argmax(logits[:, -1:, :cfg.vocab_size],
                            axis=-1).astype(jnp.int32)], axis=1)
    for t in range(S0, total - 1):
        logits, caches = M.decode_step(params, tokens[:, -1:], caches,
                                       jnp.int32(t), cfg)
        tokens = jnp.concatenate(
            [tokens, jnp.argmax(logits[:, :1, :cfg.vocab_size],
                                axis=-1).astype(jnp.int32)], axis=1)
    np.testing.assert_array_equal(np.asarray(res.tokens),
                                  np.asarray(tokens))


def test_engine_serves_token_streams(smollm):
    """GenerateExecutor through the batcher: sealed prompts in, sealed
    full sequences out, bit-exact vs the trusted oracle on the same
    padded batch (greedy sampling makes the stream deterministic)."""
    cfg, params = smollm
    prompt_len, new = 6, 4
    ex = G.GenerateExecutor(cfg, params, prompt_len=prompt_len,
                            max_new_tokens=new,
                            integrity=IG.IntegrityPolicy.full(k=2))
    assert ex.attested_digest == ex.dplan.digest != ex.plan.digest
    engine = ServingEngine(EngineConfig(max_batch=4, max_wait_ms=50.0))
    engine.register_executor("smollm-gen", ex, input_key="tokens",
                             input_dtype="int32")
    assert engine.attest("smollm-gen").plan_digest == ex.dplan.digest
    rng = np.random.default_rng(0)
    prompts, keys, futs = [], [], []
    try:
        for rid in range(4):                    # full bucket
            toks = rng.integers(0, cfg.vocab_size,
                                size=(prompt_len,)).astype(np.float32)
            key = rng.integers(0, 2 ** 32 - 1, size=(2,), dtype=np.uint32)
            box = PrivateInferenceServer.client_seal(key, toks, rid)
            futs.append(engine.submit(
                "smollm-gen", Request(rid=rid, box=box,
                                      shape=(prompt_len,),
                                      session_key=key)))
            prompts.append(toks.astype(np.int64))
            keys.append(key)
        outs = []
        for rid, (f, key) in enumerate(zip(futs, keys)):
            resp = f.result(timeout=300)
            assert resp.ok, resp
            out = PrivateInferenceServer.client_open(
                key, resp.box, (prompt_len + new,))
            outs.append(out.astype(np.int64))
    finally:
        engine.close()
    oracle = G.private_generate(
        params, jnp.asarray(np.stack(prompts), jnp.int32), cfg,
        max_new_tokens=new, trusted=True, executor=ex,
        key=jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.stack(outs),
                                  np.asarray(oracle.tokens))
    assert engine.stats.completed == 4
