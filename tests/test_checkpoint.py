"""Checkpointing: atomicity, bitwise resume, async, reshard-on-load."""
import json
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import checkpoint as C
from tests._subproc import check


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(5, dtype=jnp.int32),
                       "scale": jnp.float32(2.5)}}


def test_save_load_roundtrip(tmp_path):
    t = _tree()
    C.save(tmp_path, 3, t)
    loaded, manifest = C.load(tmp_path, jax.tree.map(jnp.zeros_like, t))
    assert manifest["step"] == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_and_gc(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        C.save(tmp_path, s, t, keep=2)
    assert C.latest_step(tmp_path) == 5
    kept = sorted(p.name for p in Path(tmp_path).glob("step_*"))
    assert len(kept) == 2


def test_no_partial_checkpoint_on_failure(tmp_path, monkeypatch):
    t = _tree()
    C.save(tmp_path, 1, t)

    def boom(*a, **k):
        raise OSError("disk full")

    monkeypatch.setattr(np, "savez", boom)
    with pytest.raises(OSError):
        C.save(tmp_path, 2, t)
    # step 1 intact, no tmp dirs or step 2 remnants
    assert C.latest_step(tmp_path) == 1
    assert not list(Path(tmp_path).glob(".tmp_*"))
    C.load(tmp_path, jax.tree.map(jnp.zeros_like, t))


def test_structure_mismatch_rejected(tmp_path):
    C.save(tmp_path, 1, _tree())
    wrong = {"w": jnp.zeros((8, 16))}
    with pytest.raises(AssertionError):
        C.load(tmp_path, wrong)


def test_async_checkpointer(tmp_path):
    t = _tree()
    ac = C.AsyncCheckpointer(tmp_path)
    ac.save(7, t, meta={"loss": 1.0})
    ac.wait()
    loaded, m = C.load(tmp_path, jax.tree.map(jnp.zeros_like, t))
    assert m["meta"]["loss"] == 1.0
    np.testing.assert_array_equal(np.asarray(loaded["w"]),
                                  np.asarray(t["w"]))


def test_async_error_propagates(tmp_path):
    blocker = tmp_path / "file"
    blocker.write_text("not a dir")          # mkdir under a file must fail
    ac = C.AsyncCheckpointer(blocker / "ckpt")
    ac.save(1, _tree())
    with pytest.raises(BaseException):
        ac.wait()


@pytest.mark.slow
def test_reshard_on_load_across_meshes(tmp_path):
    """Save sharded over 4 devices, load sharded over 2 — elastic restart."""
    out = check(f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.runtime import checkpoint as C

devs = jax.devices()
mesh4 = jax.sharding.Mesh(np.array(devs[:4]), ("data",))
mesh2 = jax.sharding.Mesh(np.array(devs[:2]), ("data",))
x = jnp.arange(32.0).reshape(8, 4)
xs = jax.device_put(x, NamedSharding(mesh4, P("data", None)))
C.save({str(tmp_path)!r}, 5, {{"x": xs}})
target = {{"x": jnp.zeros((8, 4))}}
sh = {{"x": NamedSharding(mesh2, P("data", None))}}
loaded, m = C.load({str(tmp_path)!r}, target, shardings=sh)
assert loaded["x"].sharding.mesh.shape["data"] == 2
np.testing.assert_array_equal(np.asarray(loaded["x"]), np.asarray(x))
print("RESHARD_OK")
""", n_devices=8)
    assert "RESHARD_OK" in out
