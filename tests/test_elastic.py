"""Elastic re-mesh planning (runtime/elastic.py): survive device loss.

The checkpoint-restart path the module documents: a degraded job picks
the largest feasible (data, model) mesh for the surviving devices, the
launcher re-meshes onto them and rescales the batch to keep per-device
batch constant. plan/rescale are pure functions tested in-process;
``remesh`` builds a real jax.sharding.Mesh over fake CPU devices in a
subprocess (the main test process must keep exactly 1 device).
"""
from __future__ import annotations

import pytest

from repro.runtime.elastic import (MeshCandidate, plan_degraded_mesh,
                                   remesh, rescale_batch)
from tests._subproc import check


def test_plan_model_axis_is_power_of_two_divisor():
    for healthy in range(1, 33):
        cand = plan_degraded_mesh(healthy)
        data, model = cand.shape
        assert model & (model - 1) == 0, (healthy, cand)   # power of two
        assert model <= 16                                  # prefer_model
        assert data * model == cand.devices_needed <= healthy
        assert cand.axes == ("data", "model")
        # largest feasible: doubling the model axis must not fit
        assert model * 2 > min(16, healthy)


def test_plan_prefer_model_caps_tp_degree():
    cand = plan_degraded_mesh(8, prefer_model=4)
    assert cand.shape == (2, 4)
    cand = plan_degraded_mesh(8, prefer_model=1)
    assert cand.shape == (8, 1)


def test_plan_single_device_edge():
    cand = plan_degraded_mesh(1)
    assert cand.shape == (1, 1)
    assert cand.devices_needed == 1
    with pytest.raises(AssertionError):
        plan_degraded_mesh(0)


def test_plan_non_power_of_two_survivors():
    # 3 survivors: TP=2 is the largest power-of-two, one device idles
    cand = plan_degraded_mesh(3)
    assert cand.shape == (1, 2)
    assert cand.devices_needed == 2


def test_remesh_single_device_in_process():
    import jax
    mesh = remesh(plan_degraded_mesh(1), devices=jax.devices())
    assert mesh.shape == {"data": 1, "model": 1}


def test_remesh_on_fake_cpu_devices():
    # lose 4 of 8 devices: the degraded plan still meshes the survivors
    out = check("""
        import jax
        from repro.runtime.elastic import plan_degraded_mesh, remesh
        devs = jax.devices()
        assert len(devs) == 8, devs
        healthy = devs[:4]                      # 4 "survived"
        cand = plan_degraded_mesh(len(healthy))
        assert cand.shape == (1, 4), cand
        mesh = remesh(cand, devices=healthy)
        assert mesh.shape == {"data": 1, "model": 4}, mesh.shape
        assert set(mesh.devices.flat) == set(healthy)
        # full fleet for contrast
        full = remesh(plan_degraded_mesh(len(devs)), devices=devs)
        assert full.shape == {"data": 1, "model": 8}
        print("ok", cand.devices_needed)
    """, n_devices=8)
    assert "ok 4" in out


def test_rescale_batch_round_trips():
    # shrink 4 -> 2 data shards, then grow back: per-device batch constant
    assert rescale_batch(32, 4, 2) == 16
    assert rescale_batch(16, 2, 4) == 32
    assert rescale_batch(rescale_batch(32, 4, 2), 2, 4) == 32
    # identity
    assert rescale_batch(32, 4, 4) == 32
    # tiny global batch never rescales to zero
    assert rescale_batch(2, 4, 4) == 4          # floor: 1 per device
    assert rescale_batch(1, 1, 3) == 3


def test_mesh_candidate_is_frozen():
    cand = MeshCandidate(shape=(1, 2), axes=("data", "model"),
                         devices_needed=2)
    with pytest.raises(Exception):
        cand.shape = (2, 2)
