"""End-to-end private serving: attest -> seal -> blinded infer -> unseal."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import model as M
from repro.privacy.data import make_batch
from repro.runtime.serving import PrivateInferenceServer, Request


@pytest.fixture(scope="module")
def server():
    cfg = get_smoke("vgg16")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, PrivateInferenceServer(cfg, params, mode="origami",
                                       max_batch=4)


def _request(cfg, rid, rng):
    img = make_batch(rid, 1, cfg.image_size)[0]
    key = rng.integers(0, 2 ** 32 - 1, size=(2,), dtype=np.uint32)
    box = PrivateInferenceServer.client_seal(key, img, rid)
    return Request(rid=rid, box=box, shape=img.shape, session_key=key), key


def test_end_to_end_private_inference(server, rng):
    cfg, srv = server
    reqs, keys = zip(*[_request(cfg, i, rng) for i in range(6)])
    responses = srv.serve(list(reqs))
    assert len(responses) == 6 and all(r.ok for r in responses)
    logits = PrivateInferenceServer.client_open(
        keys[0], responses[0].box, (cfg.num_classes,))
    assert np.isfinite(logits).all()
    # result matches direct (non-private) execution of the same image
    direct = np.asarray(srv.executor.reference(
        {"images": np.asarray(make_batch(0, 1, cfg.image_size))}),
        np.float32)[0]
    rel = np.abs(logits - direct).max() / (np.abs(direct).max() + 1e-9)
    assert rel < 0.05, rel


def test_corrupted_request_rejected(server, rng):
    cfg, srv = server
    req, key = _request(cfg, 99, rng)
    bad = Request(rid=99, box=req.box._replace(
        ciphertext=req.box.ciphertext.at[0, 0, 0].add(3)),
        shape=req.shape, session_key=req.session_key)
    responses = srv.serve([bad])
    assert len(responses) == 1 and not responses[0].ok


def test_batching_pads_and_preserves_order(server, rng):
    cfg, srv = server
    reqs, keys = zip(*[_request(cfg, 10 + i, rng) for i in range(5)])
    responses = srv.serve(list(reqs))      # 4 + 1 across two batches
    assert [r.rid for r in responses] == [10, 11, 12, 13, 14]
    assert all(r.ok for r in responses)


def test_serve_batch_rejects_over_max_batch(server, rng):
    cfg, srv = server
    reqs, _ = zip(*[_request(cfg, 20 + i, rng) for i in range(5)])
    with pytest.raises(ValueError, match="max_batch"):
        srv.serve_batch(list(reqs))        # seed silently dropped the tail


def test_failed_mac_never_reaches_executor(server, rng):
    """Invalid requests are filtered before padding: no inference slot, no
    blinded dispatch, no batch-counter bump."""
    cfg, srv = server
    good, _ = _request(cfg, 30, rng)
    bad_src, _ = _request(cfg, 31, rng)
    bad = Request(rid=31, box=bad_src.box._replace(
        ciphertext=bad_src.box.ciphertext.at[0, 0, 0].add(3)),
        shape=bad_src.shape, session_key=bad_src.session_key)

    batches_before = srv.batches
    responses = srv.serve_batch([bad])     # all-invalid batch
    assert [r.ok for r in responses] == [False]
    assert srv.batches == batches_before   # executor never ran

    responses = srv.serve_batch([good, bad])
    by_rid = {r.rid: r for r in responses}
    assert by_rid[30].ok and not by_rid[31].ok
    assert srv.batches == batches_before + 1


def test_duplicate_rids_all_served(server, rng):
    """Legacy contract: duplicate rids get real answers (the engine
    serializes them into waves rather than rejecting the second)."""
    cfg, srv = server
    req, key = _request(cfg, 77, rng)
    responses = srv.serve([req, req])
    assert [r.rid for r in responses] == [77, 77]
    assert all(r.ok for r in responses)


def test_serve_batch_duplicate_rid_positional(server, rng):
    """A valid and a tampered request sharing a rid must not collapse:
    responses are positional, so the valid one keeps its logits."""
    cfg, srv = server
    good, _ = _request(cfg, 88, rng)
    bad = Request(rid=88, box=good.box._replace(
        ciphertext=good.box.ciphertext.at[0, 0, 0].add(3)),
        shape=good.shape, session_key=good.session_key)
    responses = srv.serve_batch([good, bad])
    assert responses[0].ok and not responses[1].ok


def test_response_nonce_uses_full_rid_and_direction_tag(server, rng):
    """Two rids differing only in their high 32 bits must not share a
    response (key, nonce) pair, and responses must never collide with the
    request nonce of the same rid."""
    from repro.runtime.serving import request_nonce, response_nonce
    lo, hi = 7, 7 + (1 << 32)
    assert not np.array_equal(response_nonce(lo), response_nonce(hi))
    assert response_nonce(lo).shape != request_nonce(lo).shape

    # end-to-end with a high-bit rid: seal/unseal round-trips
    cfg, srv = server
    rid = (1 << 40) + 3
    req, key = _request(cfg, rid, rng)
    responses = srv.serve_batch([req])
    assert responses[0].ok
    logits = PrivateInferenceServer.client_open(key, responses[0].box,
                                                (cfg.num_classes,))
    assert np.isfinite(logits).all()
