"""End-to-end private serving: attest -> seal -> blinded infer -> unseal."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import model as M
from repro.privacy.data import make_batch
from repro.runtime.serving import PrivateInferenceServer, Request


@pytest.fixture(scope="module")
def server():
    cfg = get_smoke("vgg16")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, PrivateInferenceServer(cfg, params, mode="origami",
                                       max_batch=4)


def _request(cfg, rid, rng):
    img = make_batch(rid, 1, cfg.image_size)[0]
    key = rng.integers(0, 2 ** 32 - 1, size=(2,), dtype=np.uint32)
    box = PrivateInferenceServer.client_seal(key, img, rid)
    return Request(rid=rid, box=box, shape=img.shape, session_key=key), key


def test_end_to_end_private_inference(server, rng):
    cfg, srv = server
    reqs, keys = zip(*[_request(cfg, i, rng) for i in range(6)])
    responses = srv.serve(list(reqs))
    assert len(responses) == 6 and all(r.ok for r in responses)
    logits = PrivateInferenceServer.client_open(
        keys[0], responses[0].box, (cfg.num_classes,))
    assert np.isfinite(logits).all()
    # result matches direct (non-private) execution of the same image
    direct = np.asarray(srv.executor.reference(
        {"images": np.asarray(make_batch(0, 1, cfg.image_size))}),
        np.float32)[0]
    rel = np.abs(logits - direct).max() / (np.abs(direct).max() + 1e-9)
    assert rel < 0.05, rel


def test_corrupted_request_rejected(server, rng):
    cfg, srv = server
    req, key = _request(cfg, 99, rng)
    bad = Request(rid=99, box=req.box._replace(
        ciphertext=req.box.ciphertext.at[0, 0, 0].add(3)),
        shape=req.shape, session_key=req.session_key)
    responses = srv.serve([bad])
    assert len(responses) == 1 and not responses[0].ok


def test_batching_pads_and_preserves_order(server, rng):
    cfg, srv = server
    reqs, keys = zip(*[_request(cfg, 10 + i, rng) for i in range(5)])
    responses = srv.serve(list(reqs))      # 4 + 1 across two batches
    assert [r.rid for r in responses] == [10, 11, 12, 13, 14]
    assert all(r.ok for r in responses)
