#!/usr/bin/env bash
# Tier-1 CPU verification — the exact command ROADMAP.md names.
# Pallas kernels run under interpret=True on CPU (bit-exact vs oracles);
# the hypothesis shim in tests/conftest.py keeps the property tests
# collectable without the dependency.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
# serving-engine smoke: mixed vgg16/vgg19 through the async engine,
# logits cross-checked bit-exactly against the legacy synchronous server
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m repro.launch.serve --smoke --engine
