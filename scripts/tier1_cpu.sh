#!/usr/bin/env bash
# Tier-1 CPU verification — the exact command ROADMAP.md names.
# Pallas kernels run under interpret=True on CPU (bit-exact vs oracles);
# the hypothesis shim in tests/conftest.py keeps the property tests
# collectable without the dependency.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
# serving-engine smoke: mixed vgg16/vgg19 through the async engine,
# logits cross-checked bit-exactly against the legacy synchronous server
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m repro.launch.serve --smoke --engine
# integrity smoke: sampled Freivalds policy at rate 1.0 with a dishonest
# device flipping bits — the drill fails unless every corruption is
# detected, recovered (responses stay bit-exact vs the honest legacy
# server) and the backend quarantined (DESIGN.md §9)
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m repro.launch.serve --smoke --engine --models vgg16 \
    --requests 16 --verify sampled --verify-rate 1.0 --inject bit_flip
# plan-equivalence smoke: a mixed enclave/blinded tier-1 PlacementPlan
# (inexpressible as any legacy mode string) through the async engine,
# cross-checked bit-exactly against the synchronous path on the same plan
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m repro.launch.serve --smoke --engine --models vgg16 \
    --requests 8 --plan mixed
# sharded-offload smoke: a mixed plan served over 2 simulated devices
# with device 1 dishonest — the drill fails unless every corruption is
# caught by the SHARD-local Freivalds check, only the bad shard is
# re-dispatched, quarantine is per-DEVICE (device 0 keeps serving
# blinded offload; the model is never quarantined), and responses stay
# bit-exact vs the single-device legacy server (DESIGN.md §11)
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m repro.launch.serve --smoke --engine --models vgg16 \
    --requests 8 --plan mixed --devices 2 --shard rows --inject bit_flip
# observability smoke: the same sharded drill with span tracing on — the
# trace artifact (queue -> batch -> plan steps -> shard dispatches ->
# verify -> unseal, DESIGN.md §13) must come out as valid Chrome-trace
# JSON with a connected tree, and the metrics snapshot must carry the §14
# phase decomposition (per-profile criticals summing to the request wall
# within 10%); CI uploads trace_tier1.json + metrics_tier1.json
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m repro.launch.serve --smoke --engine --models vgg16 \
    --requests 8 --plan mixed --devices 2 --shard rows --inject bit_flip \
    --verify full --trace-out trace_tier1.json \
    --metrics-out metrics_tier1.json --postmortem-dir postmortem_tier1
python - <<'PY'
import json
doc = json.load(open("trace_tier1.json"))
ev = [e for e in doc["traceEvents"] if e["ph"] == "X"]
roots = [e for e in ev if e["name"] == "request"]
assert roots and len(ev) > len(roots), (len(ev), len(roots))
names = {e["name"] for e in ev}
need = {"request", "queue", "batch", "unseal", "plan.segment",
        "shard.dispatch", "verify", "seal"}
assert need <= names, need - names
print(f"[trace] OK: {len(ev)} spans, {len(roots)} requests, "
      f"kinds={sorted({e['cat'] for e in ev})}")
m = json.load(open("metrics_tier1.json"))
ph = m["phases"]
assert ph["requests"] == len(roots), (ph["requests"], len(roots))
for key, prof in ph["profiles"].items():
    err = abs(prof["critical_sum_s"] - prof["wall_s"])
    assert err <= 0.10 * prof["wall_s"] + 1e-9, (key, prof)
# the dishonest device triggered verify-failure post-mortem bundles, and
# every bundle is redaction-safe JSON (spans carry shapes/timings only)
assert m["flight_recorder"]["dumps"] > 0, m["flight_recorder"]
import glob
bundles = glob.glob("postmortem_tier1/postmortem_*.json")
assert bundles, "no post-mortem bundle written"
for b in bundles:
    json.load(open(b))
print(f"[phases] OK: {ph['requests']} requests decomposed, "
      f"{len(bundles)} post-mortem bundle(s)")
PY
# compile-once smoke (DESIGN.md §15): AOT-warm every shape bucket at
# register time with a persistent on-disk compilation cache — the request
# path must pay ZERO compile seconds (that is the compile-once contract),
# responses stay bit-exact vs the legacy oracle, and the cache stats land
# in aot_tier1.json (uploaded alongside metrics_tier1.json)
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m repro.launch.serve --smoke --engine --models vgg16 \
    --requests 8 --aot-warm --compile-cache-dir .aot_cache_tier1 \
    --metrics-out aot_tier1.json
python - <<'PY'
import json
m = json.load(open("aot_tier1.json"))
aot = m["aot"]
assert aot["compiles"] > 0, aot
assert aot["request_compile_seconds"] == 0.0, aot
assert m["ttfb_warm_s"] < 1.0, m["ttfb_warm_s"]
print(f"[aot] OK: {aot['compiles']} compile(s) all off the request path "
      f"({aot['compile_seconds']:.1f}s warmup), stores={aot['stores']} "
      f"ttfb_warm={m['ttfb_warm_s'] * 1e3:.0f}ms buckets={m['buckets']}")
PY
# liveness chaos smoke: scripted crash on device 0 + hang on device 1
# (total blackout), a session-refill fault window and a sealing-
# corruption window — the drill fails unless every future resolves, the
# engine degrades to verified enclave-only serving and recovers
# automatically via breaker half-open probes, seal-window requests are
# rejected with mac_failed and nothing else, and every served response
# stays bit-exact vs the healthy oracle (DESIGN.md §12)
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m repro.launch.serve --smoke --engine --models vgg16 \
    --devices 2 --chaos "dev0.crash@1-2,dev1.hang@1-2,refill@7-8,seal@10"
# private-decode smoke (DESIGN.md §16): blinded ring-fed autoregressive
# generation on the smollm smoke config with full per-step Freivalds —
# tokens AND logits must be bit-exact vs the trusted=True enclave oracle,
# every offloaded op verified, one ring slot consumed per decode step;
# CI uploads decode_tier1.json
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'PY'
import json
import jax
import numpy as np
from repro.configs import get_smoke
from repro.core import integrity as IG
from repro.models import model as M
from repro.runtime import generate as G

cfg = get_smoke("smollm_135m")
params = M.init_params(cfg, jax.random.PRNGKey(0))
prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0,
                            cfg.vocab_size)
kw = dict(max_new_tokens=6, integrity=IG.IntegrityPolicy.full(k=2),
          session_key=jax.random.PRNGKey(9))
priv = G.private_generate(params, prompt, cfg, **kw)
oracle = G.private_generate(params, prompt, cfg, trusted=True, **kw)
assert np.array_equal(np.asarray(priv.tokens), np.asarray(oracle.tokens))
assert np.array_equal(np.asarray(priv.logits), np.asarray(oracle.logits))
assert priv.telemetry.device_matmuls > 0 and priv.telemetry.verify_ops > 0
assert priv.integrity.ok and priv.integrity.n_checked == priv.integrity.n_ops
assert priv.ring["consumed"] == priv.decode_steps, priv.ring
json.dump({"plan_digest": priv.plan_digest,
           "decode_steps": priv.decode_steps,
           "verified_ops": int(priv.integrity.n_checked),
           "device_matmuls": int(priv.telemetry.device_matmuls),
           "ring": priv.ring,
           "tier1_cache_bytes": G.tier1_cache_bytes(cfg, 2, 12),
           "bitexact_vs_trusted": True},
          open("decode_tier1.json", "w"), indent=1)
print(f"[decode] OK: {priv.decode_steps} private decode steps bit-exact "
      f"vs trusted oracle, {int(priv.integrity.n_checked)} ops verified, "
      f"ring={priv.ring}")
PY
