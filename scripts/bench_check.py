#!/usr/bin/env python
"""Direction-aware bench regression gate (DESIGN.md §14).

Compares fresh ``BENCH_*.json`` artifacts against the committed baselines
in ``benchmarks/baselines/`` using per-metric tolerance bands:

- ``lower``-is-better metrics (latencies, overheads) fail when
  ``fresh > base * (1 + rel) + abs``;
- ``higher``-is-better metrics (throughput, speedups, detection rates,
  pass flags) fail when ``fresh < base * (1 - rel) - abs``.

Bands are deliberately generous for wall-clock metrics (CI runners are
shared and noisy — the gate catches structural regressions, not jitter)
and tight for correctness-flavored ones (detection rates, pass booleans:
those never legitimately regress). A missing metric in a fresh artifact
fails loudly — silent disappearance of a measured bar is itself a
regression. Baselines are refreshed deliberately via ``--write-baselines``
(never automatically), so a slow drift needs a reviewed commit to become
the new normal.

Usage::

    python scripts/bench_check.py                  # gate fresh vs committed
    python scripts/bench_check.py --write-baselines  # re-seed baselines
    python scripts/bench_check.py --fresh-dir /tmp/x --suites serving
"""
from __future__ import annotations

import argparse
import json
import pathlib
import shutil
import sys
from typing import Any, Dict, List, Optional

# suite -> artifact name (mirrors benchmarks/run.py RECORDED_SUITES)
FILES = {
    "blinding": "BENCH_blinding.json",
    "serving": "BENCH_serving.json",
    "integrity": "BENCH_integrity.json",
    "plans": "BENCH_plans.json",
    "offload": "BENCH_offload.json",
    "chaos": "BENCH_chaos.json",
    "trace": "BENCH_trace_overhead.json",
    "attribution": "BENCH_attribution.json",
    "decode": "BENCH_decode.json",
}

# (dotted path into results, direction, rel band, abs band)
SPECS: Dict[str, List[tuple]] = {
    "serving": [
        ("load_burst.achieved_rps", "higher", 0.60, 0.0),
        ("load_50rps.achieved_rps", "higher", 0.30, 0.0),
        ("engine.time_to_first_batch_s", "lower", 1.50, 0.0),
        # compile-once contract: warm ttfb must stay sub-second — a
        # reappearing request-path compile would blow straight through
        # this band (generous rel absorbs shared-runner jitter only)
        ("engine.ttfb_warm_s", "lower", 1.50, 0.2),
    ],
    "blinding": [
        ("blinding/vgg16_t1l1_fused_pre.us", "lower", 1.00, 0.0),
        ("blinding/vgg16_t1l2_fused.us", "lower", 1.00, 0.0),
    ],
    "integrity": [
        # pct-point overheads: absolute band (tiny baselines, rel is noise)
        ("overhead.full_k1.overhead_pct", "lower", 0.0, 10.0),
        # correctness: full-policy detection NEVER regresses
        ("detection.bit_flip.full_k1.detection_rate", "higher", 0.0, 0.0),
        ("detection.row_swap.full_k1.detection_rate", "higher", 0.0, 0.0),
    ],
    "plans": [
        ("origami.us", "lower", 1.00, 0.0),
        ("mixed.us", "lower", 1.00, 0.0),
    ],
    "offload": [
        ("scaling.rows_2dev.speedup_vs_1dev", "higher", 0.40, 0.0),
        ("hedging.speedup", "higher", 0.40, 0.0),
    ],
    "chaos": [
        ("classes.crash.detection_s", "lower", 5.00, 0.5),
        ("engine.liveness.recoveries", "higher", 0.0, 0.0),
    ],
    "trace": [
        ("engine_mixed_plan.overhead_pct", "lower", 0.0, 10.0),
        ("span_micro.span_us", "lower", 2.00, 0.0),
    ],
    "attribution": [
        ("decomposition.max_profile_err_pct", "lower", 0.0, 5.0),
        ("decomposition.pass", "higher", 0.0, 0.0),
        ("calibration.pass", "higher", 0.0, 0.0),
        ("calibration.improvement_x", "higher", 0.90, 0.0),
    ],
    "decode": [
        # correctness: private decode must stay bit-exact vs the trusted
        # oracle — this never legitimately regresses
        ("private.parity_bitexact", "higher", 0.0, 0.0),
        ("private.integrity_ok", "higher", 0.0, 0.0),
        ("private.verified_ops", "higher", 0.0, 0.0),
        # throughput: generous wall-clock bands (shared CI runners)
        ("private.tokens_per_s", "higher", 0.60, 0.0),
        ("trusted.tokens_per_s", "higher", 0.60, 0.0),
        ("open.tokens_per_s", "higher", 0.60, 0.0),
    ],
}


# "present but explicitly null" — distinct from missing: a null metric
# (e.g. offered_rps of the closed-loop burst) is declared not-applicable
# and is skipped, while a metric that vanished outright still fails loudly
_NULL = object()


def _get(doc: Dict[str, Any], dotted: str):
    node: Any = doc.get("results", doc)
    for part in dotted.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    if node is None:
        return _NULL
    if isinstance(node, bool):
        return 1.0 if node else 0.0
    return float(node) if isinstance(node, (int, float)) else None


def check_metric(base: float, fresh: float, direction: str,
                 rel: float, abs_band: float) -> bool:
    """True when ``fresh`` is within the regression band of ``base``."""
    if direction == "lower":
        return fresh <= base * (1.0 + rel) + abs_band
    return fresh >= base * (1.0 - rel) - abs_band


def check_suite(suite: str, base_doc: Dict, fresh_doc: Dict) -> List[str]:
    """Failure messages for one suite (empty = pass)."""
    fails = []
    for dotted, direction, rel, abs_band in SPECS.get(suite, ()):
        base = _get(base_doc, dotted)
        fresh = _get(fresh_doc, dotted)
        if fresh is _NULL:
            # explicit JSON null: declared not-applicable for this run
            print(f"  [skip] {suite}.{dotted}: null in fresh artifact")
            continue
        if base is None or base is _NULL:
            # baseline predates this metric (or declared it n/a):
            # nothing to regress against
            print(f"  [skip] {suite}.{dotted}: not in baseline")
            continue
        if fresh is None:
            fails.append(f"{suite}.{dotted}: missing from fresh artifact "
                         f"(baseline {base:g})")
            continue
        ok = check_metric(base, fresh, direction, rel, abs_band)
        band = (f"{direction}, rel={rel:g}" +
                (f", abs={abs_band:g}" if abs_band else ""))
        mark = "ok  " if ok else "FAIL"
        print(f"  [{mark}] {suite}.{dotted}: base={base:g} "
              f"fresh={fresh:g} ({band})")
        if not ok:
            fails.append(f"{suite}.{dotted}: {fresh:g} vs baseline "
                         f"{base:g} ({band})")
    return fails


def main() -> int:
    root = pathlib.Path(__file__).resolve().parent.parent
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-dir", default=str(root / "benchmarks"
                                                 / "baselines"))
    ap.add_argument("--fresh-dir", default=str(root),
                    help="where the fresh BENCH_*.json live (repo root)")
    ap.add_argument("--suites", nargs="*", default=None,
                    help="subset to check (default: every suite with a "
                         "committed baseline)")
    ap.add_argument("--write-baselines", action="store_true",
                    help="seed/refresh baselines from the fresh artifacts "
                         "instead of checking")
    args = ap.parse_args()
    base_dir = pathlib.Path(args.baseline_dir)
    fresh_dir = pathlib.Path(args.fresh_dir)
    suites = args.suites or sorted(FILES)

    if args.write_baselines:
        base_dir.mkdir(parents=True, exist_ok=True)
        for suite in suites:
            src = fresh_dir / FILES[suite]
            if src.exists():
                shutil.copyfile(src, base_dir / FILES[suite])
                print(f"seeded {base_dir / FILES[suite]}")
        return 0

    all_fails: List[str] = []
    checked = 0
    for suite in suites:
        base_path = base_dir / FILES[suite]
        fresh_path = fresh_dir / FILES[suite]
        if not base_path.exists():
            print(f"[skip] {suite}: no committed baseline {base_path}")
            continue
        if not fresh_path.exists():
            # a suite that was gated before must keep producing artifacts
            all_fails.append(f"{suite}: fresh artifact {fresh_path} missing")
            print(f"[FAIL] {suite}: fresh artifact missing")
            continue
        print(f"[{suite}] {fresh_path} vs {base_path}")
        all_fails += check_suite(suite, json.loads(base_path.read_text()),
                                 json.loads(fresh_path.read_text()))
        checked += 1
    print(f"\nbench_check: {checked} suite(s), "
          f"{len(all_fails)} regression(s)")
    for f in all_fails:
        print(f"  REGRESSION: {f}")
    return 1 if all_fails else 0


if __name__ == "__main__":
    sys.exit(main())
